"""Fleet-simulator throughput vs the Python event-heap orchestrator.

Both engines replay the same strategy on the same workload (scenario-1
per-node service mix replicated over the fleet, arrival window scaled to
keep the paper's ~2x overload per node), so requests/sec is apples to
apples per (fleet size, policy) cell.  Cells:

* ``random`` / ``least_loaded`` — the host engine's fast path (CPython
  heapq + C-speed list ops); fleetsim pays the device's fixed per-step op
  cost, so on a CPU backend it trails these (see BENCH_fleetsim.json for
  the recorded ratios and EXPERIMENTS.md §Fleetsim for the analysis);
* ``batched_feasible`` — the cross-node admission-scoring policy (the
  fleet-feasibility kernel's workload): the host router must round-trip to
  the device per forwarding decision, fleetsim keeps everything resident —
  this is where the >= 10x target at 32+ nodes is measured;
* ``sweep`` — the fleetsim-only dimension: a vmapped (seeds) batch as ONE
  device call, reported as sweep cells/sec and aggregate requests/sec.

Run:  PYTHONPATH=src python benchmarks/fleetsim_bench.py [--smoke] [--full]
      (--full adds the very slow python batched_feasible @ 256 cell;
       default writes BENCH_fleetsim.json next to the repo root)
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

try:                                     # `python -m benchmarks.run`
    from benchmarks._timing import cold_warm, timed
except ImportError:                      # `python benchmarks/fleetsim_bench.py`
    from _timing import cold_warm, timed

from repro.core.block_queue import FastPreferentialQueue
from repro.core.scenarios import SCENARIOS
from repro.fleetsim import (RequestArrays, SimParams, simulate, simulate_fn,
                            topology_arrays)
from repro.orchestration import (Orchestrator, Router, Topology,
                                 UniformWorkload)

JSON_DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleetsim.json")


def make_fleet_workload(n_nodes: int, div: int = 4) -> UniformWorkload:
    """Scenario-1 node mixes tiled over the fleet; window scaled by ``div``
    so every node sees the paper's overload intensity with 1/div volume."""
    counts = [{s: max(1, c // div) for s, c in SCENARIOS[1][i % 3].items()}
              for i in range(n_nodes)]
    return UniformWorkload(counts, window=110_000.0 / div,
                           name=f"fleet{n_nodes}_div{div}")


def bench_python(wl: UniformWorkload, topology: Topology, policy: str,
                 seed: int = 0) -> Tuple[float, dict]:
    requests = wl.generate(seed)
    orch = Orchestrator(topology, FastPreferentialQueue,
                        Router(topology, policy, seed=seed))
    dt, res = timed(lambda: orch.run(requests))
    return len(requests) / dt, dict(met_rate=res.met_rate,
                                    forwards=res.forwards)


def bench_fleetsim(wl: UniformWorkload, topology: Topology, policy: str,
                   capacity: int, depth: int, use_pallas: bool = False,
                   forwards_hint: Optional[int] = None) -> Tuple[float, dict]:
    """Steady-state requests/sec (warm call: same trace cache, new seed).

    The event-time scan's length is a sizing knob like ``capacity``:
    ``forwards_hint`` (the Python engine's realized forward count, when
    that cell ran) sizes it with generous slack; without a hint a probe
    run at the worst-case ``R * (max_forwards + 1)`` bound measures it.
    Either way ``event_overflow`` is asserted 0, so the sizing cannot
    silently clip the run.
    """
    ta = topology_arrays(topology)
    reqs, _ = wl.to_arrays(0)
    R = reqs.arrival.shape[0]
    if forwards_hint is None:
        probe = simulate(reqs, ta, SimParams.make(0), policy=policy,
                         capacity=capacity, depth=depth,
                         use_pallas=use_pallas)
        forwards_hint = int(probe.forwards)
    max_events = min(3 * R, R + 4 * forwards_hint + 256)
    kw = dict(policy=policy, capacity=capacity, depth=depth,
              use_pallas=use_pallas, max_events=max_events)
    # cold call on seed 0 (compile + run), warm measurement on seed 1 —
    # same compiled executable, fresh forwarding stream
    cw = cold_warm(lambda: simulate(reqs, ta, SimParams.make(0), **kw),
                   lambda: simulate(reqs, ta, SimParams.make(1), **kw))
    m = cw.result
    assert int(m.overflow) == 0 and int(m.window_saturation) == 0, \
        f"capacity {capacity}/depth {depth} saturated"
    assert int(m.event_overflow) == 0, \
        f"event plane saturated (max_events {max_events})"
    return R / cw.warm_s, dict(met_rate=float(m.met_rate),
                               forwards=int(m.forwards),
                               cold_rps=round(R / cw.cold_s))


def bench_sweep(wl: UniformWorkload, topology: Topology, n_seeds: int,
                capacity: int, depth: int) -> Tuple[float, float, int]:
    """One vmapped device call over ``n_seeds`` forwarding streams.

    Returns (sweep cells/sec, aggregate requests/sec, total requests).
    """
    ta = topology_arrays(topology)
    reqs, _ = wl.to_arrays(0)
    reqs = RequestArrays(*(jnp.asarray(a) for a in reqs))
    ta = type(ta)(*(jnp.asarray(a) for a in ta))
    R = reqs.arrival.shape[0]
    tgt = jnp.full((R, 2), -1, jnp.int32)
    run = simulate_fn(policy="random", capacity=capacity, depth=depth)
    sweep = jax.vmap(run, in_axes=(None, None, SimParams(0, 0), None))

    def params(lo):
        return SimParams(jnp.arange(lo, lo + n_seeds, dtype=jnp.int32),
                         jnp.full((n_seeds,), 1.0, jnp.float32))

    # cold on seeds [0, n), warm on fresh seeds [n, 2n) — same executable
    cw = cold_warm(lambda: sweep(reqs, ta, params(0), tgt),
                   lambda: sweep(reqs, ta, params(n_seeds), tgt))
    m, dt = cw.result, cw.warm_s
    # the sweep keeps the exact worst-case event bound (per-seed forward
    # counts differ; undersizing would surface here, never silently)
    assert int(jnp.max(m.event_overflow)) == 0
    return n_seeds / dt, n_seeds * R / dt, n_seeds * R


def run(smoke: bool = False, full: bool = False,
        json_path: Optional[str] = None) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    record = []
    div = 40 if smoke else 4
    sizes = (3, 32) if smoke else (3, 32, 256)
    # per-cell (python?, fleetsim?) — python batched_feasible is O(device
    # round-trip per forward): minutes at 32 nodes, ~hours at 256
    policies = {
        3: ["random", "least_loaded"],
        32: ["random", "least_loaded", "batched_feasible"],
        256: ["random", "least_loaded", "batched_feasible"],
    }
    for K in sizes:
        wl = make_fleet_workload(K, div)
        topo = Topology.full_mesh(K)
        cap = 256 if smoke else (4096 if K == 3 else 1024)
        dep = 128 if smoke else (1024 if K == 3 else 512)
        for policy in policies[K]:
            skip_py = policy == "batched_feasible" and (
                smoke or (K >= 256 and not full))
            py_rps, hint = None, None
            if not skip_py:
                py_rps, py_info = bench_python(wl, topo, policy)
                hint = py_info["forwards"]      # sizes the event plane
            # exercise the Pallas kernel (interpret off-TPU) in the smoke
            # cell so CI covers it; the measured cells use the jnp reference
            use_pallas = smoke and policy == "batched_feasible"
            fs_rps, fs_info = bench_fleetsim(wl, topo, policy, cap, dep,
                                             use_pallas=use_pallas,
                                             forwards_hint=hint)
            ratio = (fs_rps / py_rps) if py_rps else float("nan")
            tag = f"{fs_rps:,.0f} req/s fleetsim"
            if py_rps:
                tag += f" vs {py_rps:,.0f} python = {ratio:.2f}x"
            rows.append((f"fleetsim_{K}n_{policy}", 1e6 / fs_rps, tag))
            record.append(dict(nodes=K, policy=policy,
                               python_rps=py_rps and round(py_rps),
                               fleetsim_rps=round(fs_rps),
                               fleetsim_cold_rps=fs_info["cold_rps"],
                               ratio=py_rps and round(ratio, 3),
                               met_rate=round(fs_info["met_rate"], 4),
                               forwards=fs_info["forwards"]))
        # one vmapped sweep cell per fleet size
        n_seeds = 2 if smoke else 8
        cells_ps, agg_rps, n_req = bench_sweep(wl, topo, n_seeds, cap, dep)
        rows.append((f"fleetsim_{K}n_sweep{n_seeds}", 1e6 / agg_rps,
                     f"{cells_ps:.2f} cells/s, {agg_rps:,.0f} req/s "
                     f"aggregate ({n_req} req, one device call)"))
        record.append(dict(nodes=K, policy=f"sweep[{n_seeds} seeds]",
                           fleetsim_rps=round(agg_rps),
                           cells_per_s=round(cells_ps, 3)))
    if json_path:
        payload = dict(
            backend=jax.default_backend(), jax=jax.__version__,
            regime=(f"scenario-1 per-node mix / {div}, window "
                    f"{110_000.0 / div:.0f}, full mesh, ~{2000 // div} "
                    f"req/node, seeds 0-1"),
            rows=record,
            notes=("random/least_loaded: host engine is CPython heapq + "
                   "C-speed list ops and wins on a CPU backend (fixed "
                   "per-step op-dispatch cost dominates fleetsim there); "
                   "batched_feasible: cross-node admission scoring — the "
                   "host router round-trips to the device per forward, "
                   "fleetsim stays resident (the >= 10x cell at 32+ "
                   "nodes).  Sweep rows are one vmapped device call."),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleets, CI-friendly runtime, Pallas "
                         "interpret path exercised")
    ap.add_argument("--full", action="store_true",
                    help="include python batched_feasible @ 256 nodes "
                         "(very slow)")
    ap.add_argument("--json", default=None,
                    help=f"write the JSON baseline (default "
                         f"{JSON_DEFAULT} unless --smoke)")
    args = ap.parse_args()
    json_path = args.json or (None if args.smoke else JSON_DEFAULT)
    for name, us, derived in run(args.smoke, args.full, json_path):
        print(f"{name},{us:.2f},{derived}", flush=True)


if __name__ == "__main__":
    main()
