"""Roofline table from dry-run artifacts (results/dryrun/*.json).

One row per (arch × shape × mesh): the three roofline terms, dominant
bottleneck, useful-FLOPs ratio — EXPERIMENTS.md §Roofline is generated from
this.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional, Tuple


def load_results(outdir: str = "results/dryrun") -> List[dict]:
    rows = []
    for p in sorted(Path(outdir).glob("*.json")):
        try:
            rows.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return rows


def table(outdir: str = "results/dryrun_final",
          mesh: Optional[str] = None) -> List[Tuple[str, float, str]]:
    rows = []
    for r in load_results(outdir):
        if r.get("status") == "skipped":
            rows.append((f"dryrun_{r['arch']}_{r['shape']}_skip", 0.0,
                         "skipped: " + r.get("reason", "")[:60]))
            continue
        if r.get("status") != "ok":
            rows.append((f"dryrun_{r['arch']}_{r['shape']}_{r.get('mesh')}",
                         0.0, "FAILED"))
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        t_max = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        derived = (f"c={rf['t_compute_s']:.3g}s m={rf['t_memory_s']:.3g}s "
                   f"x={rf['t_collective_s']:.3g}s "
                   f"dom={rf['bottleneck']} "
                   f"frac={rf['roofline_fraction']:.3f} "
                   f"useful={rf['useful_flops_ratio']:.2f}")
        rows.append((name, t_max * 1e6, derived))
    return rows


def markdown_table(outdir: str = "results/dryrun_final") -> str:
    lines = ["| arch | shape | mesh | t_compute | t_memory | t_collective | "
             "bottleneck | roofline frac | useful ratio |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in load_results(outdir):
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['t_compute_s']:.4g}s | {rf['t_memory_s']:.4g}s "
            f"| {rf['t_collective_s']:.4g}s | {rf['bottleneck']} "
            f"| {rf['roofline_fraction']:.3f} "
            f"| {rf['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)
