"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract).

    PYTHONPATH=src python -m benchmarks.run             # full suite
    PYTHONPATH=src python -m benchmarks.run --quick     # fewer seeds
    PYTHONPATH=src python -m benchmarks.run --only fig5
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer seeds")
    ap.add_argument("--seeds", type=int, default=None,
                    help="paper uses 40; default 10 (3 with --quick)")
    ap.add_argument("--only", default=None,
                    help="substring filter on section names")
    args = ap.parse_args()
    seeds = args.seeds or (3 if args.quick else 10)

    sections = []

    from benchmarks import fleetsim_bench, netsim_bench, orchestrator_bench, \
        paper_tables, profile_report, queue_bench, roofline_report, \
        serving_bench
    sections.append(("fig5_fig6", lambda: paper_tables.fig5_fig6(seeds)))
    sections.append(("ablations",
                     lambda: paper_tables.ablations(max(3, seeds // 2))))
    sections.append(("queue_microbench", lambda: queue_bench.run(
        depths=(100, 1000) if args.quick else (100, 1000, 4000))))
    sections.append(("orchestrator_throughput", lambda: orchestrator_bench.run(
        seeds=(0,) if args.quick else (0, 1))))
    # full runs refresh the committed BENCH_fleetsim.json baseline
    sections.append(("fleetsim_throughput", lambda: fleetsim_bench.run(
        smoke=args.quick,
        json_path=None if args.quick else fleetsim_bench.JSON_DEFAULT)))
    # full runs refresh the committed BENCH_netsim.json baseline
    sections.append(("netsim_sweep", lambda: netsim_bench.run(
        smoke=args.quick,
        json_path=None if args.quick else netsim_bench.JSON_DEFAULT)))
    sections.append(("serving_engine", lambda: serving_bench.run(
        n_requests=30 if args.quick else 60)))
    # full runs refresh the committed BENCH_profile.json attribution
    sections.append(("scan_profile", lambda: profile_report.run(
        smoke=args.quick,
        json_path=None if args.quick else profile_report.JSON_DEFAULT)))
    sections.append(("roofline", lambda: roofline_report.table(
        "results/dryrun_final")))

    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}")
        except Exception as e:   # keep the suite going; report the failure
            print(f"{name}_FAILED,0,{type(e).__name__}: {e}", file=sys.stderr)
            print(f"{name}_FAILED,0,{type(e).__name__}")
        print(f"# section {name} took {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
