"""Orchestrator hot-loop throughput: events processed per second.

The event heap is the orchestration plane's hot path — every arrival,
forward and completion passes through it.  This bench drives the unified
core on the paper's scenario-1 workload (overload regime, so the forward
path is exercised hard) and reports events/sec per (queue, topology)
configuration, giving the perf trajectory a number to move.

Run:  PYTHONPATH=src python benchmarks/orchestrator_bench.py [--smoke]
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.block_queue import FastPreferentialQueue
from repro.core.queues import EDFQueue, FIFOQueue
from repro.orchestration import Orchestrator, Router, Topology, get_workload

_QUEUES = {
    "fifo": FIFOQueue,
    "preferential": FastPreferentialQueue,
    "edf": EDFQueue,
}


def bench_orchestrator(queue_kind: str, topology: Topology,
                       seeds=(0, 1)) -> Tuple[float, int]:
    """(events per second, events per run) on paper/scenario1."""
    wl = get_workload("paper/scenario1")
    events = 0
    elapsed = 0.0
    for seed in seeds:
        requests = wl.generate(seed)           # outside the timed region
        orch = Orchestrator(topology, _QUEUES[queue_kind],
                            Router(topology, seed=seed))
        t0 = time.perf_counter()
        res = orch.run(requests)
        elapsed += time.perf_counter() - t0
        events += res.events
    return events / elapsed, events // len(seeds)


def run(seeds=(0, 1)) -> List[Tuple[str, float, str]]:
    rows = []
    mesh = Topology.full_mesh(3)
    ring = Topology.ring(6)
    for kind in _QUEUES:
        eps, n_events = bench_orchestrator(kind, mesh, seeds)
        rows.append((f"orchestrator_mesh3_{kind}", 1e6 / eps,
                     f"{eps / 1e3:.0f}k events/s ({n_events} events)"))
    eps, n_events = bench_orchestrator("preferential", ring, seeds)
    rows.append(("orchestrator_ring6_preferential", 1e6 / eps,
                 f"{eps / 1e3:.0f}k events/s ({n_events} events)"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single seed, CI-friendly runtime")
    args = ap.parse_args()
    for name, us, derived in run(seeds=(0,) if args.smoke else (0, 1)):
        print(f"{name},{us:.2f},{derived}")
