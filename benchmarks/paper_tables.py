"""Paper reproduction benchmarks — Figs. 5 and 6 of Boing et al. (2022).

Fig. 5: % requests answered within deadline, FIFO vs preferential queue,
scenarios 1-3.  Fig. 6: forwarding rate (of max possible referrals).
Plus the ablations discussed in DESIGN.md §2 (forced-push compaction
reading) and beyond-paper comparisons (EDF, smarter forwarding).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core.simulator import run_experiment

PAPER_DELTAS = {  # published improvements (preferential - FIFO), pp
    1: {"met": 2.92, "fwd": 2.61},
    2: {"met": 5.97, "fwd": 6.49},
    3: {"met": 0.01, "fwd": 0.43},
}


def fig5_fig6(n_seeds: int = 10) -> List[Tuple[str, float, str]]:
    rows = []
    for scenario in (1, 2, 3):
        t0 = time.time()
        res: Dict[str, object] = {}
        for queue in ("fifo", "preferential"):
            res[queue] = run_experiment(scenario, queue, n_seeds=n_seeds)
        us = (time.time() - t0) / max(1, 2 * n_seeds) * 1e6
        f, p = res["fifo"], res["preferential"]
        dmet = 100 * (p.met_rate_mean - f.met_rate_mean)
        dfwd = 100 * (f.forward_rate_mean - p.forward_rate_mean)
        rows.append((f"fig5_s{scenario}_fifo_met_pct", us,
                     f"{100 * f.met_rate_mean:.2f}"))
        rows.append((f"fig5_s{scenario}_pref_met_pct", us,
                     f"{100 * p.met_rate_mean:.2f}"))
        rows.append((f"fig5_s{scenario}_delta_pp", us,
                     f"{dmet:+.2f} (paper {PAPER_DELTAS[scenario]['met']:+.2f})"))
        rows.append((f"fig6_s{scenario}_fifo_fwd_pct", us,
                     f"{100 * f.forward_rate_mean:.2f}"))
        rows.append((f"fig6_s{scenario}_pref_fwd_pct", us,
                     f"{100 * p.forward_rate_mean:.2f}"))
        rows.append((f"fig6_s{scenario}_delta_pp", us,
                     f"{dfwd:+.2f} (paper {PAPER_DELTAS[scenario]['fwd']:+.2f})"))
    return rows


def ablations(n_seeds: int = 6) -> List[Tuple[str, float, str]]:
    rows = []
    t0 = time.time()
    cases = [
        ("pref_compact_literal", dict(queue="preferential_compact")),
        ("edf_exact_admission", dict(queue="edf")),
        ("pref_discard_beraldi9", dict(queue="preferential",
                                       discard_on_exhaust=True)),
        ("pref_po2_forwarding", dict(queue="preferential",
                                     forward_policy="power_of_two")),
        ("pref_least_loaded_fwd", dict(queue="preferential",
                                       forward_policy="least_loaded")),
        ("fifo_po2_forwarding", dict(queue="fifo",
                                     forward_policy="power_of_two")),
    ]
    for name, kw in cases:
        res = run_experiment(1, n_seeds=n_seeds, **kw)
        us = (time.time() - t0) / n_seeds * 1e6
        rows.append((f"ablate_s1_{name}_met_pct", us,
                     f"{100 * res.met_rate_mean:.2f}"))
        t0 = time.time()
    return rows
