"""Per-phase wall-time attribution of the fleetsim event-time scan.

The scan's step is a fixed pipeline — event pop, referral scoring (link
cost), admission feasibility, the insert cascade, and the terminal
scatters (``fleetsim.*`` named scopes in ``fleetsim/core.py``).  This
report measures where a warm step actually spends its time:

* each phase runs standalone as a jitted ``lax.scan`` of M iterations
  over representative shapes (the same (K, W) ledger windows and (B,)
  event buffer a real step touches), with a scalar carry threading a
  data dependency through every iteration so XLA cannot dead-code or
  batch the work — the per-iteration time is the phase's amortized cost;
* one real warm :func:`repro.fleetsim.simulate` call (cold/warm split
  via ``benchmarks._timing``) gives the true end-to-end step time; the
  gap between it and the phase sum is reported as ``residual`` — glue
  ops, scan overhead, and fusion effects the standalone cells cannot
  see.  Attribution is a profile, not an identity: phases measured alone
  lose cross-phase fusion, so the residual can be negative.

Output: ``BENCH_profile.json`` (per-phase us/step + fraction of the
measured step) and the usual ``name,us_per_call,derived`` CSV rows.
``--trace`` additionally captures a ``jax.profiler`` trace of the warm
run (viewable at ui.perfetto.dev, like the host engine's
``TraceRecorder`` output — see EXPERIMENTS.md §Telemetry).

Run:  PYTHONPATH=src python benchmarks/profile_report.py [--smoke]
      [--trace DIR] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

try:                                     # `python -m benchmarks.run`
    from benchmarks._timing import cold_warm
    from benchmarks.fleetsim_bench import make_fleet_workload
except ImportError:                      # `python benchmarks/profile_report.py`
    from _timing import cold_warm
    from fleetsim_bench import make_fleet_workload

from repro.core import jax_queue as jq
from repro.fleetsim import SimParams, simulate, topology_arrays
from repro.kernels import ref as kref
from repro.orchestration import Topology

JSON_DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_profile.json")

#: tiny coupling constant: folds each phase's outputs back into the scan
#: carry so no iteration is dead code, while perturbing inputs by an
#: amount that never changes control flow
_EPS = 1e-20


def _per_iter(fn, iters: int) -> float:
    """Amortized seconds per call of ``fn(x: f32 scalar) -> f32 scalar``,
    measured as a warm jitted ``lax.scan`` of ``iters`` iterations."""
    @jax.jit
    def run(x0):
        def body(x, _):
            return fn(x), None
        x, _ = jax.lax.scan(body, x0, None, length=iters)
        return x
    cw = cold_warm(lambda: run(jnp.float32(0.0)))
    return cw.warm_s / iters


def _tsum(*arrays) -> jnp.ndarray:
    return sum(jnp.sum(a.astype(jnp.float32)) for a in arrays)


def phase_cells(K: int, W: int, B: int, R: int):
    """The five measured phases over representative step shapes.

    Returns ``[(name, fn)]`` where each ``fn`` maps the f32 carry to a
    new carry through one execution of that phase's ops.
    """
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    f = jnp.float32
    starts = jnp.sort(jax.random.uniform(ks[0], (K, W), jnp.float32,
                                         0.0, 1e4), axis=1)
    ends = starts + jax.random.uniform(ks[1], (K, W), jnp.float32, 1.0, 50.0)
    sizes = ends - starts
    nq = jnp.full((K,), W // 2, jnp.int32)
    busy = jax.random.uniform(ks[2], (K,), jnp.float32, 0.0, 1e3)
    head = jnp.zeros((K,), jnp.int32)
    lat = jax.random.uniform(ks[3], (K, K), jnp.float32, 0.0, 10.0)
    ibw = jax.random.uniform(ks[4], (K, K), jnp.float32, 0.0, 1.0)
    ev_time = jnp.sort(jax.random.uniform(ks[5], (B,), jnp.float32,
                                          0.0, 1e4))
    ev_rid = jnp.arange(B, dtype=jnp.int32) % R
    ev_meta = jnp.arange(B, dtype=jnp.int32) % (K * 4)
    ev_n = jnp.int32(B // 2)
    sw, ew, zw = starts[0], ends[0], sizes[0]
    srw = jnp.arange(W, dtype=jnp.int32)

    def event_pop(x):
        # the two-way merge + buffer pop of fleetsim.event_pop
        t_a, t_b = f(5e3) + x, ev_time[0]
        take_fresh = t_a <= t_b
        t = jnp.where(take_fresh, t_a, t_b)
        et, (er, em), n = jq.event_pop(ev_time + x, (ev_rid, ev_meta),
                                       ev_n, ~take_fresh)
        return x + _EPS * (_tsum(et, er, em) + t + n)

    def link_cost(x):
        feas, arr, load = kref.link_cost_ref(
            starts + x, ends, sizes, nq, ends[:, 0] / f(2.0), f(8e3),
            busy, head, f(4e3), lat[0], ibw[0], f(1.5))
        return x + _EPS * _tsum(feas, arr, load)

    def feasibility(x):
        ok, j, cap, load = kref.fleet_search_ref(
            starts + x, ends, sizes, nq, ends[:, 0] / f(2.0), f(8e3),
            jnp.maximum(busy, f(4e3)), head)
        return x + _EPS * _tsum(ok, j, cap, load)

    def admission(x):
        ns, ne, nz, admitted, (nsr,) = jq.insert_at(
            sw + x, ew, zw, jnp.int32(0), nq[0], jnp.bool_(True),
            jnp.bool_(False), jnp.int32(W // 2), ew[W // 2], f(7.0),
            f(4e3), meta=(srw,), meta_vals=(jnp.int32(3),))
        return x + _EPS * (_tsum(ns, ne, nz, nsr) + admitted)

    completion = jnp.zeros((R,), jnp.float32)
    reqinfo = jnp.zeros((R,), jnp.int32)

    def scatter(x):
        # the terminal-record writes of fleetsim.scatter: the windowed
        # dynamic_update_slice plus the two (R,) mode="drop" scatters
        cur = jnp.int32(0)
        st = jax.lax.dynamic_update_slice(starts, (sw + x)[None, :],
                                          (cur, jnp.int32(0)))
        nqs = nq.at[cur].add(1)
        c = completion.at[jnp.int32(R // 2)].set(f(5e3) + x, mode="drop")
        ri = reqinfo.at[jnp.int32(R // 2)].set(jnp.int32(7), mode="drop")
        return x + _EPS * _tsum(st, nqs, c, ri)

    return [("event_pop", event_pop), ("link_cost", link_cost),
            ("feasibility", feasibility), ("admission", admission),
            ("scatter", scatter)]


def measure_total(K: int, div: int, capacity: int, depth: int,
                  trace_dir: Optional[str] = None):
    """Warm end-to-end step time of a real run (batched_feasible — the
    kernel-bearing policy the phases model)."""
    wl = make_fleet_workload(K, div)
    topo = Topology.full_mesh(K)
    ta = topology_arrays(topo)
    reqs, _ = wl.to_arrays(0)
    R = reqs.arrival.shape[0]
    probe = simulate(reqs, ta, SimParams.make(0), policy="batched_feasible",
                     capacity=capacity, depth=depth)
    max_events = min(3 * R, R + 4 * int(probe.forwards) + 256)
    kw = dict(policy="batched_feasible", capacity=capacity, depth=depth,
              max_events=max_events)
    cw = cold_warm(lambda: simulate(reqs, ta, SimParams.make(0), **kw),
                   lambda: simulate(reqs, ta, SimParams.make(1), **kw))
    assert int(cw.result.event_overflow) == 0
    if trace_dir is not None:
        try:
            with jax.profiler.trace(trace_dir):
                jax.block_until_ready(
                    simulate(reqs, ta, SimParams.make(1), **kw))
            print(f"# jax.profiler trace written under {trace_dir} "
                  f"(load in ui.perfetto.dev)")
        except Exception as e:          # profiling is best-effort extra
            print(f"# jax.profiler trace skipped: {type(e).__name__}: {e}")
    return cw, max_events, R


def run(smoke: bool = False, json_path: Optional[str] = None,
        trace_dir: Optional[str] = None) -> List[Tuple[str, float, str]]:
    K = 8 if smoke else 32
    W = 64 if smoke else 512
    B = 256 if smoke else 1024
    div = 40 if smoke else 8
    capacity = 256 if smoke else 1024
    iters = 200 if smoke else 1000

    cw, steps, R = measure_total(K, div, capacity, W, trace_dir)
    step_us = cw.warm_s / steps * 1e6

    phases = []
    for name, fn in phase_cells(K, W, B, R):
        us = _per_iter(fn, iters) * 1e6
        phases.append((name, us))
    phase_sum = sum(us for _, us in phases)
    residual = step_us - phase_sum

    rows: List[Tuple[str, float, str]] = []
    rows.append((f"profile_{K}n_step_total", step_us,
                 f"{steps} steps, warm {cw.warm_s:.3f}s "
                 f"(cold {cw.cold_s:.3f}s), {R} req"))
    for name, us in phases:
        rows.append((f"profile_{K}n_{name}", us,
                     f"{100 * us / step_us:.1f}% of the measured step"))
    rows.append((f"profile_{K}n_residual", residual,
                 f"{100 * residual / step_us:.1f}% — scan glue + fusion "
                 f"effects standalone cells cannot see"))

    if json_path:
        payload = dict(
            backend=jax.default_backend(), jax=jax.__version__,
            regime=(f"{K} nodes full mesh, batched_feasible, depth {W}, "
                    f"event buffer {B}, scenario-1 mix / {div}"),
            step_us=round(step_us, 3),
            steps=steps,
            cold_s=round(cw.cold_s, 3), warm_s=round(cw.warm_s, 3),
            phases={name: dict(us_per_step=round(us, 3),
                               fraction=round(us / step_us, 4))
                    for name, us in phases},
            residual_us=round(residual, 3),
            residual_fraction=round(residual / step_us, 4),
            notes=("Phases are standalone jitted lax.scan microbenchmarks "
                   "over representative step shapes, amortized per "
                   "iteration; step_us is a real warm batched_feasible "
                   "run divided by its scan length.  The residual is the "
                   "un-attributed remainder (scan glue, fusion) — "
                   "attribution is a profile, not an identity."),
        )
        with open(json_path, "w") as fjs:
            json.dump(payload, fjs, indent=1)
            fjs.write("\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, CI-friendly runtime")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="also capture a jax.profiler trace of the warm "
                         "run under DIR (Perfetto-viewable)")
    ap.add_argument("--json", default=None,
                    help=f"write the JSON report (default {JSON_DEFAULT} "
                         f"unless --smoke)")
    args = ap.parse_args()
    json_path = args.json or (None if args.smoke else JSON_DEFAULT)
    for name, us, derived in run(args.smoke, json_path, args.trace):
        print(f"{name},{us:.2f},{derived}", flush=True)


if __name__ == "__main__":
    main()
