"""Shared cold/warm timing for the benchmark suite.

Every device-side benchmark in this repo reports the same split: the
**cold** row is the first call of a jitted executable (compile + run —
recorded, never the throughput number) and the **warm** row is a second
call of the same compiled executable (the steady-state figure).  The
``perf_counter`` + ``block_until_ready`` boilerplate lived copy-pasted
in each bench; this module is the one implementation.

``jax.block_until_ready`` is pytree-aware, so ``timed`` blocks on every
leaf the benched function returns — a bench cannot accidentally time
only the first output of an async dispatch.
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax


class ColdWarm(NamedTuple):
    """One cold (compile + run) and one warm (steady-state) measurement;
    ``result`` is the warm call's output, fully materialized."""
    cold_s: float
    warm_s: float
    result: Any


def timed(fn: Callable[[], Any]) -> Tuple[float, Any]:
    """Wall-clock one call of ``fn``, blocking on everything it returns.

    Returns ``(seconds, result)``.  Host-side functions pass through
    ``block_until_ready`` untouched, so the same helper times both
    engines.
    """
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return time.perf_counter() - t0, out


def cold_warm(cold_fn: Callable[[], Any],
              warm_fn: Optional[Callable[[], Any]] = None) -> ColdWarm:
    """The standard two-call protocol.

    ``cold_fn`` runs first (its timing folds in JIT compilation);
    ``warm_fn`` (default: ``cold_fn`` again — same args, same compiled
    executable) runs second and its result is returned.  Benches that
    warm up on one input and measure on another — e.g. fleetsim's
    seed-0 warm-up, seed-1 measurement — pass both.
    """
    cold_s, _ = timed(cold_fn)
    warm_s, result = timed(warm_fn if warm_fn is not None else cold_fn)
    return ColdWarm(cold_s=cold_s, warm_s=warm_s, result=result)
