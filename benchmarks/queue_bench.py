"""Queue micro-benchmarks: push/pop cost vs depth, faithful vs fast.

Quantifies the beyond-paper O(log n) feasibility search (DESIGN.md §2)
against the paper's O(n) tail→head walk.
"""
from __future__ import annotations

import random
import time
from typing import List, Tuple

from repro.core.block_queue import FastPreferentialQueue, PreferentialQueue
from repro.core.queues import FIFOQueue
from repro.core.request import Request, Service


def _requests(n: int, seed: int = 0) -> List[Request]:
    rng = random.Random(seed)
    out = []
    t = 0.0
    for _ in range(n):
        t += rng.uniform(0, 5)
        p = rng.choice([20.0, 44.0, 180.0])
        d = rng.choice([4000.0, 9000.0])
        svc = Service(f"p{p}", 1, "bench", p, d)
        out.append(Request(service=svc, arrival_time=t, origin_node=0))
    return out


def bench_queue(queue_cls, n: int, seed: int = 0) -> float:
    """Seconds per push (amortized) at depth ~n under overload."""
    reqs = _requests(n, seed)
    q = queue_cls()
    t0 = time.perf_counter()
    for r in reqs:
        q.push(r, cpu_free_time=r.arrival_time, forced=True)
    return (time.perf_counter() - t0) / n


def run(depths=(100, 1000, 4000)) -> List[Tuple[str, float, str]]:
    """CSV rows (name, us_per_push, derived) across queue depths."""
    rows = []
    for n in depths:
        t_faith = bench_queue(PreferentialQueue, n)
        t_fast = bench_queue(FastPreferentialQueue, n)
        t_fifo = bench_queue(FIFOQueue, n)
        rows.append((f"queue_push_faithful_n{n}", t_faith * 1e6,
                     f"{t_faith * 1e6:.1f}us"))
        rows.append((f"queue_push_fast_n{n}", t_fast * 1e6,
                     f"speedup {t_faith / max(t_fast, 1e-12):.1f}x"))
        rows.append((f"queue_push_fifo_n{n}", t_fifo * 1e6,
                     f"{t_fifo * 1e6:.2f}us"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shallow depths only, CI-friendly runtime")
    args = ap.parse_args()
    for name, us, derived in run(depths=(100, 500) if args.smoke
                                 else (100, 1000, 4000)):
        print(f"{name},{us:.2f},{derived}")
