"""netsim throughput + fidelity: the network axis as a device-resident
sweep, vs the event-heap path paying per-hop Python costs.

Cells:

* ``grid`` — the fleetsim-only dimension this PR opens: a full
  **latency × bandwidth × sla_scale** grid, with the network itself a
  vmap axis (stacked :class:`repro.netsim.NetParams`) nested over the
  ``SimParams`` sla axis — every cell of the cube is computed in ONE
  device call.  Reported as sweep cells/sec and aggregate requests/sec.
* ``host`` — honest CPU ratios: the event-heap ``Orchestrator`` runs the
  same campus-priced workload (it pays a ``transfer_delay`` lookup and a
  later heap event per forward), fleetsim runs it device-resident.
  **Cold and warm are separate rows**: the first device call includes
  JIT compilation and must not pollute the throughput number, so the
  timed row is a warm second call of the same compiled executable and
  the cold (compile + run) time is recorded next to it.  On a CPU
  backend the Python heap is fast — the recorded ratio is honest about
  that, as with BENCH_fleetsim.json; the grid rows are where the device
  wins (the host cannot amortize a 27-cell cube at all).
* ``fidelity`` — met-rate delta between the two engines under the campus
  network, measured by forwarding-trace replay so rng streams are
  factored out.  The event-time scan (DESIGN.md §7) replays the heap's
  priced event interleaving exactly, so the delta is asserted to be
  **zero** — this row regression-guards the exactness, it no longer
  measures an approximation.

Run:  PYTHONPATH=src python benchmarks/netsim_bench.py [--smoke]
      (default writes BENCH_netsim.json next to the repo root)
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_queue import FastPreferentialQueue
from repro.fleetsim import (NetParams, RequestArrays, SimParams, simulate,
                            simulate_fn, topology_arrays)
from repro.fleetsim.validate import run_validation
from repro.netsim import LinkModel
from repro.orchestration import Orchestrator, Router, Topology
try:                                     # `python -m benchmarks.run`
    from benchmarks._timing import cold_warm, timed
    from benchmarks.fleetsim_bench import make_fleet_workload
except ImportError:                      # `python benchmarks/netsim_bench.py`
    from _timing import cold_warm, timed
    from fleetsim_bench import make_fleet_workload

JSON_DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_netsim.json")


def bench_grid(wl, topology: Topology, lams, inv_bws, slas,
               capacity: int, depth: int) -> Tuple[float, float, dict]:
    """The (latency × bandwidth × sla) cube as ONE device call (warm;
    the cold compile+run time rides along in the info dict)."""
    K = topology.n_nodes
    ta = topology_arrays(topology)
    reqs, _ = wl.to_arrays(0)
    reqs = RequestArrays(*(jnp.asarray(a) for a in reqs))
    ta = type(ta)(*(jnp.asarray(a) for a in ta))
    R = reqs.arrival.shape[0]
    tgt = jnp.full((R, 2), -1, jnp.int32)

    nets = [NetParams.uniform(K, lam, ibw) for lam in lams for ibw in inv_bws]
    stacked = NetParams(
        latency=jnp.stack([n.latency for n in nets]),
        inv_bw=jnp.stack([n.inv_bw for n in nets]))
    params = SimParams(seed=jnp.zeros((len(slas),), jnp.int32),
                       sla_scale=jnp.asarray(slas, jnp.float32))

    # the cube's heaviest cells forward freely, so the event plane keeps
    # the exact worst-case bound (undersizing would surface in
    # event_overflow, asserted 0 below)
    run = simulate_fn(policy="least_loaded", capacity=capacity, depth=depth,
                      network=True)
    # inner axis: sla (SimParams), outer axis: the network itself
    cube = jax.vmap(jax.vmap(run, in_axes=(None, None, 0, None, None)),
                    in_axes=(None, None, None, None, 0))
    cw = cold_warm(lambda: cube(reqs, ta, params, tgt, stacked))
    cold_dt, dt, m = cw.cold_s, cw.warm_s, cw.result
    n_cells = len(nets) * len(slas)
    met = np.asarray(m.met_deadline)            # (nets, slas)
    info = dict(
        cells=n_cells, requests_per_cell=int(R),
        cold_s=round(cold_dt, 3), warm_s=round(dt, 3),
        met_grid=met.reshape(len(lams), len(inv_bws), len(slas)).tolist(),
        # the free-network, sla=1 corner for eyeballing the tax
        met_free=int(met[0, list(slas).index(1.0)])
        if 1.0 in slas and lams[0] == 0.0 and inv_bws[0] == 0.0 else None,
    )
    assert int(np.asarray(m.overflow).max()) == 0
    assert int(np.asarray(m.event_overflow).max()) == 0
    return n_cells / dt, n_cells * R / dt, info


def bench_host_vs_fleet(wl, topology: Topology, link: LinkModel,
                        capacity: int, depth: int, seed: int = 0):
    """Honest CPU comparison under the campus network + exact fidelity.

    Timing rows run both engines natively (least_loaded); the fleet
    number is the warm second call of one compiled executable, with the
    cold compile+run time recorded separately.  The fidelity number
    replays the host's forwarding trace (run_validation), so it compares
    dynamics — admission, timing, priced event ordering — with the rng
    stream factored out; the event-time scan makes it exactly 0.
    """
    requests = wl.generate(seed)
    orch = Orchestrator(topology, FastPreferentialQueue,
                        Router(topology, "least_loaded", seed=seed),
                        network=link)
    host_dt, host = timed(lambda: orch.run(requests))

    ta = topology_arrays(topology)
    reqs, _ = wl.to_arrays(seed, payload_fn=link.payload_of)
    net = link.net_params()
    R = len(requests)
    # size the event plane off the host's realized forward count, with
    # slack; event_overflow is asserted 0, so the sizing cannot silently
    # clip the run
    max_events = min(R * 3, R + 2 * host.forwards + 64)
    kw = dict(policy="least_loaded", capacity=capacity, depth=depth,
              net=net, max_events=max_events)
    # same seed both calls: the comparison must replay the same workload
    # cell, and the second call reuses the compiled executable
    cw = cold_warm(lambda: simulate(reqs, ta, SimParams.make(seed), **kw))
    cold_dt, warm_dt, m = cw.cold_s, cw.warm_s, cw.result
    assert int(m.overflow) == 0 and int(m.event_overflow) == 0

    # exact-fidelity regression guard: trace replay of the same cell
    rep = run_validation(wl, seed, policy="least_loaded",
                         topology=topology, network=link)
    assert rep.exact, \
        f"event-time scan must replay the priced heap exactly: {rep.row()}"
    assert rep.met_diff_pp == 0.0, rep.row()

    return (R / host_dt, R / cold_dt, R / warm_dt,
            dict(host_met_rate=round(host.met_deadline / R, 4),
                 fleet_met_rate=round(float(m.met_rate), 4),
                 fidelity_delta_pp=rep.met_diff_pp,
                 fidelity_outcome_mismatches=rep.outcome_mismatches,
                 fidelity_node_mismatches=rep.node_mismatches,
                 host_transfer_time=round(host.transfer_time, 1),
                 host_forwards=host.forwards, fleet_forwards=int(m.forwards),
                 max_events=max_events))


def run(smoke: bool = False,
        json_path: Optional[str] = None) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    record = []
    div = 40 if smoke else 8
    K = 3 if smoke else 16
    cap = 256 if smoke else 1024
    dep = 128 if smoke else 512
    wl = make_fleet_workload(K, div)
    topo = Topology.full_mesh(K)
    link = LinkModel.campus(topo)

    # -- the cube: latency × bandwidth × sla as one device call ------------
    lams = (0.0, 5.0) if smoke else (0.0, 5.0, 30.0)
    inv_bws = (0.0, 0.8) if smoke else (0.0, 0.8, 3.2)   # UT per MB
    slas = (0.5, 1.0) if smoke else (0.5, 1.0, 2.0)
    cells_ps, agg_rps, info = bench_grid(wl, topo, lams, inv_bws, slas,
                                         cap, dep)
    rows.append((f"netsim_{K}n_grid{info['cells']}", 1e6 / agg_rps,
                 f"{cells_ps:.2f} cells/s, {agg_rps:,.0f} req/s aggregate "
                 f"({info['cells']} (lat x bw x sla) cells, one device "
                 f"call; cold {info['cold_s']}s, warm {info['warm_s']}s)"))
    record.append(dict(nodes=K, kind="grid", cells=info["cells"],
                       lams=list(lams), inv_bws=list(inv_bws),
                       slas=list(slas),
                       cells_per_s=round(cells_ps, 3),
                       aggregate_rps=round(agg_rps),
                       cold_s=info["cold_s"], warm_s=info["warm_s"],
                       met_grid=info["met_grid"]))

    # -- honest host-vs-fleet single cell under the campus network ---------
    host_rps, cold_rps, warm_rps, fid = bench_host_vs_fleet(
        wl, topo, link, cap, dep)
    ratio = warm_rps / host_rps
    rows.append((f"netsim_{K}n_campus_cold", 1e6 / cold_rps,
                 f"{cold_rps:,.0f} req/s first call (JIT compile folded in "
                 f"— reported separately, not the throughput row)"))
    rows.append((f"netsim_{K}n_campus_warm", 1e6 / warm_rps,
                 f"{warm_rps:,.0f} req/s fleetsim vs {host_rps:,.0f} "
                 f"python = {ratio:.2f}x; fidelity "
                 f"{fid['fidelity_delta_pp']}pp (exact, asserted)"))
    record.append(dict(nodes=K, kind="host_vs_fleet",
                       python_rps=round(host_rps),
                       fleetsim_cold_rps=round(cold_rps),
                       fleetsim_warm_rps=round(warm_rps),
                       ratio_warm=round(ratio, 3), **fid))

    if json_path:
        payload = dict(
            backend=jax.default_backend(), jax=jax.__version__,
            regime=(f"scenario-1 per-node mix / {div}, {K} nodes full mesh, "
                    f"campus link profile (lat 5 UT, 1.25 MB/UT), "
                    f"least_loaded"),
            rows=record,
            notes=("grid rows: the network is a vmap axis (stacked "
                   "NetParams) — a latency x bandwidth x sla cube in one "
                   "device call, which the Python heap cannot amortize "
                   "at all.  host_vs_fleet: single-cell honest CPU "
                   "ratio; cold (compile + run) and warm (second call) "
                   "are separate rows so JIT warm-up never pollutes the "
                   "throughput number.  fidelity_delta_pp compares "
                   "trace-replayed dynamics under campus pricing and is "
                   "asserted exactly 0: the event-time scan (DESIGN.md "
                   "§7) replays the priced heap event for event — this "
                   "row guards the contract, it no longer measures an "
                   "approximation."),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, CI-friendly runtime")
    ap.add_argument("--json", default=None,
                    help=f"write the JSON baseline (default {JSON_DEFAULT} "
                         f"unless --smoke)")
    args = ap.parse_args()
    json_path = args.json or (None if args.smoke else JSON_DEFAULT)
    for name, us, derived in run(args.smoke, json_path):
        print(f"{name},{us:.2f},{derived}", flush=True)


if __name__ == "__main__":
    main()
