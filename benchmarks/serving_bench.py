"""Serving-engine benchmark: deadline-aware engine over a real JAX model.

Measures (a) end-to-end met-rate of FIFO vs preferential admission at a
fixed offered load, and (b) the batching win — the beyond-paper
deadline-aware batcher vs single-request execution.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:                                     # `python -m benchmarks.run`
    from benchmarks._timing import cold_warm
except ImportError:                      # `python benchmarks/serving_bench.py`
    from _timing import cold_warm

from repro.configs import get_smoke_config
from repro.core.queues import FIFOQueue
from repro.models import vit
from repro.serving.engine import (DeadlineAwareEngine, ServiceClass,
                                  ServingReplica)


def _engine(queue_kind: str, run_batch, max_batch: int, n_replicas: int = 2):
    reps = []
    for i in range(n_replicas):
        q = FIFOQueue() if queue_kind == "fifo" else None
        reps.append(ServingReplica(i, run_batch, queue=q,
                                   max_batch=max_batch))
    return DeadlineAwareEngine(reps, rng_seed=7)


def run(n_requests: int = 60) -> List[Tuple[str, float, str]]:
    cfg = get_smoke_config("deit-b")
    params = vit.init_params(cfg, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda imgs: vit.forward(params, imgs, cfg))
    img = jnp.ones((cfg.img_res, cfg.img_res, 3), jnp.float32)

    def run_batch(cls_name, payloads):
        logits = fwd(jnp.stack(payloads))
        return list(np.asarray(jnp.argmax(logits, -1)))

    run_batch(None, [img])     # compile

    rows = []
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.6, size=n_requests))
    for queue_kind in ("fifo", "preferential"):
        for max_batch in (1, 8):
            cls = ServiceClass("hd", cfg.img_res, deadline=30.0,
                               proc_time=4.0)
            cls.batch_proc_time = {1: 4.0, 2: 4.5, 4: 5.5, 8: 7.5}

            def one_run():
                eng = _engine(queue_kind, run_batch, max_batch)
                for i, at in enumerate(arrivals):
                    eng.submit(img, cls, now=float(at), origin=i % 2)
                eng.drain(float(arrivals[-1]))
                return eng

            # cold first pass (jitted forward recompiles per new batch
            # shape), warm second pass on the cached executables — the
            # reported row, same protocol as the device benches
            cw = cold_warm(one_run)
            wall, eng = cw.warm_s, cw.result
            s = eng.stats()
            met = 100 * s["met"] / max(1, s["met"] + s["missed"])
            rows.append((f"serving_{queue_kind}_b{max_batch}_met_pct",
                         wall / n_requests * 1e6,
                         f"{met:.1f} (batches={s['batches']}, "
                         f"fwd={s['forwards']})"))
    return rows
