"""A whole (seeds x deadline-thresholds) sweep as ONE device call.

The event-heap orchestrator runs one (scenario, policy, seed) cell at a
time; `repro.fleetsim` holds the fleet as stacked ledger tensors, so a
sweep grid is just `jax.vmap` over `SimParams` — here 8 forwarding seeds
x 4 SLA-tightness factors on paper scenario 1 (`sla_scale` multiplies
every relative deadline: 0.5 = twice as strict, 2.0 = twice as loose).

Run:  PYTHONPATH=src python examples/fleet_sweep.py [--scenario 1]
      [--seeds 8] [--policy random]

Cross-validation of the simulator against the event heap:
      PYTHONPATH=src python -m repro.fleetsim.validate
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleetsim import (RequestArrays, SimParams, simulate_fn,
                            topology_arrays)
from repro.orchestration import Topology, get_workload

SLA_SCALES = (0.5, 0.8, 1.0, 2.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", type=int, default=1, choices=(1, 2, 3))
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--policy", default="random",
                    choices=("random", "power_of_two", "least_loaded",
                             "round_robin", "batched_feasible"))
    args = ap.parse_args()

    wl = get_workload(f"paper/scenario{args.scenario}")
    arrays, _ = wl.to_arrays(0)
    reqs = RequestArrays(*(jnp.asarray(a) for a in arrays))
    ta = topology_arrays(Topology.full_mesh(wl.n_nodes))
    ta = type(ta)(*(jnp.asarray(a) for a in ta))
    R = int(reqs.arrival.shape[0])
    tgt = jnp.full((R, 2), -1, jnp.int32)

    run = simulate_fn(policy=args.policy, capacity=4096, depth=1024)
    # inner vmap: sla_scale axis; outer vmap: seed axis
    grid = jax.vmap(
        jax.vmap(run, in_axes=(None, None, SimParams(None, 0), None)),
        in_axes=(None, None, SimParams(0, None), None))
    params = SimParams(
        seed=jnp.arange(args.seeds, dtype=jnp.int32),
        sla_scale=jnp.asarray(SLA_SCALES, jnp.float32))

    cells = args.seeds * len(SLA_SCALES)
    print(f"scenario {args.scenario} ({R} requests, {wl.n_nodes} nodes), "
          f"policy={args.policy}: {args.seeds} seeds x {len(SLA_SCALES)} "
          f"SLA scales = {cells} cells, one vmapped call")
    m = grid(reqs, ta, params, tgt)                      # compile + run
    m.met_deadline.block_until_ready()
    t0 = time.perf_counter()
    m = grid(reqs, ta, params._replace(
        seed=params.seed + args.seeds), tgt)             # steady state
    m.met_deadline.block_until_ready()
    dt = time.perf_counter() - t0
    assert int(m.overflow.max()) == 0
    assert int(m.window_saturation.max()) == 0

    met = 100.0 * np.asarray(m.met_deadline) / R         # (seeds, scales)
    fwd = 100.0 * np.asarray(m.forwards) / R
    print(f"\n{'sla_scale':>10s} {'met% mean':>10s} {'met% sd':>8s} "
          f"{'fwd%':>7s}")
    for c, scale in enumerate(SLA_SCALES):
        print(f"{scale:10.2f} {met[:, c].mean():10.2f} "
              f"{met[:, c].std(ddof=1):8.2f} {fwd[:, c].mean():7.2f}")
    print(f"\n{cells} sweep cells ({cells * R:,} requests) in {dt:.2f}s "
          f"= {cells / dt:.1f} cells/s, {cells * R / dt:,.0f} req/s "
          f"aggregate")


if __name__ == "__main__":
    main()
