"""MEC-LB simulator CLI — explore the paper's experiment space.

Run:  PYTHONPATH=src python examples/multi_node_orchestration.py \
          --scenario 2 --queues fifo preferential edf --seeds 10
"""
import argparse

from repro.core.simulator import run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", type=int, default=1, choices=(1, 2, 3))
    ap.add_argument("--queues", nargs="+",
                    default=["fifo", "preferential"],
                    choices=["fifo", "preferential", "preferential_faithful",
                             "preferential_compact", "edf"])
    ap.add_argument("--forward-policy", default="random",
                    choices=["random", "power_of_two", "least_loaded",
                             "round_robin", "batched_feasible"])
    ap.add_argument("--seeds", type=int, default=10)
    ap.add_argument("--window", type=float, default=None,
                    help="arrival window (UT); default = calibrated 110k")
    ap.add_argument("--max-forwards", type=int, default=2)
    ap.add_argument("--discard", action="store_true",
                    help="Beraldi [9] discard-on-exhaust variant")
    args = ap.parse_args()

    kw = dict(n_seeds=args.seeds, forward_policy=args.forward_policy,
              max_forwards=args.max_forwards,
              discard_on_exhaust=args.discard)
    if args.window:
        kw["arrival_window"] = args.window

    print(f"scenario {args.scenario}, {args.seeds} seeds, "
          f"forwarding={args.forward_policy}, M={args.max_forwards}")
    print("(seed-by-seed on the event heap; for a whole (seeds x SLA) grid "
          "as ONE device call, see examples/fleet_sweep.py)")
    print(f"{'queue':24s} {'met%':>8s} {'±':>6s} {'fwd%':>8s} {'±':>6s} "
          f"{'resp':>9s}")
    for q in args.queues:
        r = run_experiment(args.scenario, q, **kw)
        print(f"{q:24s} {100 * r.met_rate_mean:8.2f} "
              f"{100 * r.met_rate_stdev:6.2f} "
              f"{100 * r.forward_rate_mean:8.2f} "
              f"{100 * r.forward_rate_stdev:6.2f} "
              f"{r.mean_response_time:9.1f}")


if __name__ == "__main__":
    main()
