"""Beyond the paper: the unified orchestration API on configurations the
legacy ``SimConfig`` could not express.

Four end-to-end demos (DESIGN.md §4 and §6 document the APIs):

1. **ring**      — scenario-2 load on a 6-node ring (forwarding restricted
                   to adjacent nodes) vs the paper's full mesh;
2. **two-tier**  — heterogeneous speeds: 4 edge sites backed by 2 cloud
                   nodes that process 4x faster;
3. **poisson**   — the paper's scenario-1 volume as Poisson streams instead
                   of the uniform arrival window, plus a diurnal variant;
4. **netsim**    — the same cluster with the campus network priced in
                   (``repro.netsim.LinkModel``): referrals cost wire time,
                   deadline slack shrinks, and the free-referral numbers
                   above stop being reachable.

Run:  PYTHONPATH=src python examples/custom_topologies.py [--seeds 3]
"""
import argparse

from repro.core.block_queue import FastPreferentialQueue
from repro.core.scenarios import DEFAULT_ARRIVAL_WINDOW, SCENARIOS
from repro.netsim import LinkModel
from repro.orchestration import (DiurnalWorkload, Orchestrator,
                                 PoissonWorkload, Router, Topology,
                                 UniformWorkload, get_workload)


def run_config(name, topology, workload, seeds, policy="random",
               network=None):
    met, fwd, disc = 0, 0, 0
    total = 0
    xfer = 0.0
    for seed in range(seeds):
        router = Router(topology, policy, seed=seed)
        orch = Orchestrator(topology, FastPreferentialQueue, router,
                            network=network)
        res = orch.run(workload.generate(seed))
        met += res.met_deadline
        fwd += res.forwards
        disc += res.discarded
        total += res.total_requests
        xfer += res.transfer_time
    wire = f"   wire {xfer / total:7.1f} UT/req" if network is not None \
        else ""
    print(f"{name:34s} met {100 * met / total:6.2f}%   "
          f"forwards/req {fwd / total:5.2f}   discarded {disc}{wire}")
    return met / total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--policy", default="random",
                    help="router policy (random | power_of_two | "
                         "least_loaded | round_robin | batched_feasible)")
    args = ap.parse_args()
    seeds = args.seeds

    print(f"== 1. ring vs full mesh (scenario-2 counts on 6 nodes, "
          f"policy={args.policy}) ==")
    counts = SCENARIOS[2] + [{"S3": 100, "S6": 100}] * 3   # pad to 6 nodes
    wl = UniformWorkload(counts, window=DEFAULT_ARRIVAL_WINDOW, name="ring-demo")
    run_config("full_mesh(6)", Topology.full_mesh(6), wl, seeds, args.policy)
    run_config("ring(6)", Topology.ring(6), wl, seeds, args.policy)
    run_config("star(6, hub=0)", Topology.star(6), wl, seeds, args.policy)

    print("\n== 2. heterogeneous two-tier: 4 edge + 2 cloud @4x ==")
    wl2 = UniformWorkload(SCENARIOS[2] + [{"S1": 50}] * 3,
                          window=DEFAULT_ARRIVAL_WINDOW, name="tier-demo")
    run_config("two_tier(cloud_speed=1)  [flat]",
               Topology.two_tier(4, n_cloud=2, cloud_speed=1.0), wl2, seeds)
    run_config("two_tier(cloud_speed=4)",
               Topology.two_tier(4, n_cloud=2, cloud_speed=4.0), wl2, seeds)

    print("\n== 3. arrival processes (scenario-1 volume, 3-node mesh) ==")
    topo = Topology.full_mesh(3)
    run_config("uniform (paper)", topo, get_workload("paper/scenario1"), seeds)
    run_config("poisson (same expected volume)", topo,
               PoissonWorkload.from_counts(SCENARIOS[1],
                                           horizon=DEFAULT_ARRIVAL_WINDOW),
               seeds)
    run_config("diurnal (2 peaks, amp 0.8)", topo,
               DiurnalWorkload(SCENARIOS[1], window=DEFAULT_ARRIVAL_WINDOW,
                               peaks=2, amplitude=0.8), seeds)

    print("\n== 4. the campus network priced in (scenario-2 load, 6-node "
          "mesh) ==")
    topo6 = Topology.full_mesh(6)
    run_config("free referrals (the paper)", topo6, wl, seeds, args.policy)
    run_config("campus links (5 UT + MB/1.25)", topo6, wl, seeds,
               args.policy, network=LinkModel.campus(topo6))
    run_config("wan links (80 UT + MB/0.125)", topo6, wl, seeds,
               args.policy, network=LinkModel.preset(topo6, "wan"))

    print("\nevery configuration above also runs device-resident: "
          "examples/fleet_sweep.py vmaps whole (seeds x SLA) grids via "
          "repro.fleetsim (cross-validated against this event heap), and "
          "examples/mobility_sweep.py adds the netsim axes — UE mobility "
          "plus a vmapped latency x bandwidth grid in one device call")


if __name__ == "__main__":
    main()
