"""netsim end-to-end: mobile UEs on a priced campus network, and the
network itself as a vmapped sweep axis.

Two parts:

1. **mobility** (event heap) — the paper's scenario-1 cameras become
   mobile UEs: cell sites front the MEC nodes, uplinks are priced, and a
   seeded handover trace re-homes traffic mid-run
   (:class:`repro.netsim.RadioWorkload`).  Handover churn + uplink tax
   vs the wired baseline, through the same ``Orchestrator``.
2. **grid** (fleetsim) — a latency × bandwidth grid of
   :class:`repro.netsim.NetParams` stacked into ONE vmapped device call
   (the sweep BENCH_netsim.json benchmarks), printed as a met-rate
   matrix: watch the referral economics flip as the wire gets slower.

Run:  PYTHONPATH=src python examples/mobility_sweep.py [--seeds 2]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_queue import FastPreferentialQueue
from repro.fleetsim import (NetParams, RequestArrays, SimParams, simulate_fn,
                            topology_arrays)
from repro.netsim import LinkModel, RadioModel, RadioWorkload
from repro.orchestration import (Orchestrator, Router, Topology,
                                 UniformWorkload, get_workload)

WL = get_workload("paper/scenario1")


def part1_mobility(seeds: int) -> None:
    print("== 1. mobile UEs on the campus radio (scenario-1 volume) ==")
    topo = Topology.full_mesh(3)
    link = LinkModel.campus(topo)

    def run(name, workload, network):
        met = fwd = total = 0
        for seed in range(seeds):
            orch = Orchestrator(topo, FastPreferentialQueue,
                                Router(topo, seed=seed), network=network)
            res = orch.run(workload.generate(seed))
            met += res.met_deadline
            fwd += res.forwards
            total += res.total_requests
        print(f"  {name:38s} met {100 * met / total:6.2f}%   "
              f"forwards/req {fwd / total:5.2f}")

    run("wired cameras, free network (paper)", WL, None)
    run("wired cameras, campus links", WL, link)
    static_radio = RadioModel.from_link(link)
    run("static UEs, campus uplink + links",
        RadioWorkload(WL, static_radio, link=link), link)
    mobile = static_radio.with_random_mobility(
        WL.n_nodes, horizon=110_000.0, handovers_per_ue=3.0, seed=0)
    run("mobile UEs (3 handovers avg)",
        RadioWorkload(WL, mobile, link=link), link)


def part2_grid() -> None:
    print("\n== 2. the network as a vmap axis: latency x bandwidth grid, "
          "one device call ==")
    K = 3
    # a hotter, smaller mix so the grid runs in seconds on CPU
    wl = UniformWorkload([{"S1": 30, "S4": 30, "S5": 25, "S6": 25}] * K,
                         window=1200.0, name="hot")
    topo = Topology.full_mesh(K)
    reqs, _ = wl.to_arrays(0)
    reqs = RequestArrays(*(jnp.asarray(a) for a in reqs))
    ta = topology_arrays(topo)
    ta = type(ta)(*(jnp.asarray(a) for a in ta))
    R = int(reqs.arrival.shape[0])
    tgt = jnp.full((R, 2), -1, jnp.int32)

    lams = (0.0, 5.0, 30.0, 120.0)
    bws = (float("inf"), 1.25, 0.3125)          # MB/UT; inf = free wire

    nets = [NetParams.uniform(K, lam, 0.0 if np.isinf(bw) else 1.0 / bw)
            for lam in lams for bw in bws]
    stacked = NetParams(latency=jnp.stack([n.latency for n in nets]),
                        inv_bw=jnp.stack([n.inv_bw for n in nets]))
    run = simulate_fn(policy="least_loaded", capacity=256, depth=128,
                      network=True)
    sweep = jax.vmap(run, in_axes=(None, None, None, None, 0))
    m = sweep(reqs, ta, SimParams.make(0), tgt, stacked)
    met = 100.0 * np.asarray(m.met_deadline).reshape(len(lams), len(bws)) / R

    hdr = "".join(f"  bw={'inf' if np.isinf(b) else b:>6}" for b in bws)
    print(f"  met-rate %, {R} requests/cell, {len(nets)} cells resident:")
    print(f"  {'latency':>8s}{hdr}")
    for i, lam in enumerate(lams):
        cells = "".join(f"  {met[i, j]:8.1f}" for j in range(len(bws)))
        print(f"  {lam:8.0f}{cells}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args()
    part1_mobility(args.seeds)
    part2_grid()


if __name__ == "__main__":
    main()
