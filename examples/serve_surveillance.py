"""End-to-end driver: the paper's campus video-surveillance use case.

Three MEC replicas serve a mixed HD / FullHD / 4K frame stream (Table I's
service classes) with real JAX vision backbones as the data plane.  Both
queue disciplines run on an identical workload; the report compares
deadline compliance and referrals — the paper's Figs. 5-6, but with actual
model execution instead of simulated processing times.

Run:  PYTHONPATH=src python examples/serve_surveillance.py [--requests N]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.queues import FIFOQueue
from repro.models import vit
from repro.serving.engine import (DeadlineAwareEngine, ServiceClass,
                                  ServingReplica, measure_step_times)


def build_data_plane():
    """One smoke-scale ViT per resolution class (stand-ins for the
    per-resolution detector models in the paper's deployment)."""
    cfg = get_smoke_config("vit-l16")
    params = vit.init_params(cfg, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda imgs: vit.forward(params, imgs, cfg))

    def run_batch(cls_name, payloads):
        logits = fwd(jnp.stack(payloads))
        return list(np.asarray(jnp.argmax(logits, -1)))

    img = jnp.ones((cfg.img_res, cfg.img_res, 3), jnp.float32)
    run_batch("warmup", [img])
    return run_batch, img


def run_discipline(kind: str, run_batch, img, classes, arrivals, mix,
                   n_replicas=3):
    reps = []
    for i in range(n_replicas):
        q = FIFOQueue() if kind == "fifo" else None
        reps.append(ServingReplica(i, run_batch, queue=q, max_batch=8))
    eng = DeadlineAwareEngine(reps, rng_seed=42)
    for i, at in enumerate(arrivals):
        cls = classes[mix[i]]
        eng.submit(img, cls, now=float(at), origin=i % n_replicas)
    eng.drain(float(arrivals[-1]))
    return eng.stats()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=90)
    args = ap.parse_args()

    run_batch, img = build_data_plane()

    # Table I mapping: 4K/FullHD/HD with pixel-proportional proc times and
    # busy(9000)/isolated(4000)-style deadline classes (engine time units).
    classes = {
        "4k": ServiceClass("4k", 3840, deadline=60.0, proc_time=18.0),
        "fhd": ServiceClass("fhd", 1920, deadline=45.0, proc_time=4.4),
        "hd": ServiceClass("hd", 1280, deadline=20.0, proc_time=2.0),
    }
    for c in classes.values():
        c.batch_proc_time = {b: c.proc_time * (1 + 0.15 * (b - 1))
                             for b in (1, 2, 4, 8)}

    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(1.2, size=args.requests))
    mix = rng.choice(["4k", "fhd", "hd"], size=args.requests,
                     p=[0.2, 0.3, 0.5])

    print(f"serving {args.requests} frames over 3 replicas "
          f"(mix: 20% 4K / 30% FHD / 50% HD)")
    for kind in ("fifo", "preferential"):
        t0 = time.time()
        s = run_discipline(kind, run_batch, img, classes, arrivals, mix)
        met_pct = 100 * s["met"] / max(1, s["met"] + s["missed"])
        print(f"  {kind:13s}: met={met_pct:5.1f}%  forwards={s['forwards']:3d} "
              f" forced={s['forced']:2d}  batches={s['batches']:3d} "
              f" [{time.time() - t0:.1f}s wall]")
    print("-> preferential admission meets more deadlines with fewer "
          "referrals, matching the paper's Figs. 5-6 on a live data plane")


if __name__ == "__main__":
    main()
