"""One run, observed twice: the telemetry plane end to end.

The same overloaded 3-node cell runs on BOTH engines (DESIGN.md §8):

* the **host** event heap streams its decisions through a
  :class:`repro.telemetry.TraceRecorder` — out come a Perfetto-viewable
  Chrome trace (one track per MEC node: queue/serve spans, wire spans
  per referral hop, discard instants) and a time-binned
  :class:`~repro.telemetry.TelemetrySummary`;
* the **device** event-time scan carries the telemetry cube
  (``simulate(..., telemetry=TelemetryConfig(...))``) and returns the
  same summary as fixed-shape tensors from a single device call.

The tour prints the queue-depth heatmap from each side, the per-kind
event totals, and the bucket-for-bucket agreement between them —
counters and occupancy high-water marks agree **exactly** (both engines
bin with the same f32 arithmetic), the derived integrals to f32-endpoint
precision.  Open the trace at https://ui.perfetto.dev.

Run:  PYTHONPATH=src python examples/telemetry_tour.py
      [--buckets 24] [--out trace.json] [--net campus]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.block_queue import FastPreferentialQueue
from repro.fleetsim import SimParams, pack_requests, simulate, \
    topology_arrays
from repro.netsim import LinkModel
from repro.orchestration import (Hooks, Orchestrator, Router, Topology,
                                 UniformWorkload)
from repro.telemetry import (TelemetryConfig, TelemetrySummary,
                             TraceRecorder, compare_summaries,
                             validate_chrome_trace)

# ~20x overload per node: plenty of queueing, forwarding and late
# completions for the heatmap to show
WORKLOAD = UniformWorkload([{"S1": 30, "S4": 30, "S5": 25, "S6": 25}] * 3,
                           window=1200.0, name="tour")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--buckets", type=int, default=24)
    ap.add_argument("--out", default="trace.json",
                    help="Chrome-trace output path (ui.perfetto.dev)")
    ap.add_argument("--net", default=None,
                    help="price referrals with a link preset "
                         "(campus/metro/wan) or 'zero'")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    topo = Topology.full_mesh(WORKLOAD.n_nodes)
    network = None
    if args.net is not None:
        network = LinkModel.zero(topo) if args.net == "zero" \
            else LinkModel.preset(topo, args.net)

    # -- host engine, recorded through its hooks ---------------------------
    requests = WORKLOAD.generate(args.seed)
    targets = {}

    def on_forward(req, src, dst, now):
        # record forwarding choices so the device run replays this exact
        # run (policy="trace") and the two summaries are comparable
        targets.setdefault(req.rid, []).append(dst.node_id)

    rec = TraceRecorder(network=network,
                        hooks=Hooks(on_forward=on_forward))
    orch = Orchestrator(topo, FastPreferentialQueue,
                        Router(topo, "least_loaded", seed=args.seed),
                        network=network, hooks=rec.hooks)
    result = orch.run(requests)
    horizon = float(result.end_time)
    print(f"host run: {result.processed} processed, "
          f"{result.met_deadline} met, {result.forwards} forwards "
          f"(horizon {horizon:.0f} UT)")

    trace = rec.write(args.out, requests, topo)
    n_events = validate_chrome_trace(trace)
    print(f"wrote {args.out}: {n_events} trace events (schema-valid; "
          f"load in ui.perfetto.dev)\n")

    host = rec.summary(requests, topo, args.buckets, horizon)
    print("host (event heap, via TraceRecorder):")
    print(host.depth_heatmap())
    print(f"  kinds: {host.kind_totals()}\n")

    # -- device engine, same cell, telemetry cube carried ------------------
    reqs, _, _ = pack_requests(
        requests, payload_fn=network.payload_of if network else None)
    tgt = np.full((len(requests), 2), -1, np.int32)
    for row, r in enumerate(requests):        # rows are request order,
        for h, dst in enumerate(targets.get(r.rid, ())):   # not rid order
            tgt[row, h] = dst
    m = simulate(reqs, topology_arrays(topo), SimParams.make(args.seed),
                 policy="trace", targets=tgt,
                 capacity=1 << max(8, int(np.ceil(np.log2(len(requests))))),
                 net=network.net_params() if network else None,
                 telemetry=TelemetryConfig(args.buckets, horizon))
    dev = TelemetrySummary.from_frame(m.telemetry)
    print("device (event-time scan, telemetry cube):")
    print(dev.depth_heatmap())
    print(f"  kinds: {dev.kind_totals()}\n")

    agr = compare_summaries(host, dev)
    print(f"agreement: {agr.row()}")
    if not agr.ok:
        raise SystemExit("telemetry contract violated (DESIGN.md §8)")
    print("occupancy high-water per bucket (in-flight referrals):")
    print("  " + " ".join(f"{int(v)}" for v in dev.occupancy_hwm))


if __name__ == "__main__":
    main()
