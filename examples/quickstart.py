"""Quickstart: the paper's deadline-aware orchestration in 60 seconds.

1. reproduce the paper's headline result (preferential > FIFO) in the
   MEC-LB simulator;
2. serve a real JAX vision model through the deadline-aware engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import SimConfig, run_simulation
from repro.configs import get_smoke_config
from repro.models import vit
from repro.serving.engine import (DeadlineAwareEngine, ServiceClass,
                                  ServingReplica)


def part1_simulator():
    print("== Part 1: MEC-LB simulator (paper §IV, scenario 1) ==")
    for queue in ("fifo", "preferential"):
        res = run_simulation(SimConfig(scenario=1, queue=queue, seed=0))
        print(f"  {queue:13s}: {res.met_rate:6.2%} deadlines met, "
              f"{res.forward_rate:6.2%} forwarding rate")
    print("  -> the preferential block queue admits tight-deadline requests "
          "into schedule gaps (Fig. 2 of the paper)\n")


def part2_serving():
    print("== Part 2: deadline-aware serving of a real JAX model ==")
    cfg = get_smoke_config("deit-b")
    params = vit.init_params(cfg, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda imgs: vit.forward(params, imgs, cfg))

    def run_batch(cls_name, payloads):
        return list(np.asarray(jnp.argmax(fwd(jnp.stack(payloads)), -1)))

    img = jnp.ones((cfg.img_res, cfg.img_res, 3), jnp.float32)
    run_batch("warmup", [img])

    hd = ServiceClass("HD", 720, deadline=40.0, proc_time=4.0)
    hd.batch_proc_time = {1: 4.0, 2: 4.6, 4: 5.8, 8: 8.0}
    engine = DeadlineAwareEngine(
        [ServingReplica(i, run_batch, max_batch=8) for i in range(2)])
    reqs = [engine.submit(img, hd, now=i * 1.0) for i in range(16)]
    engine.drain(16.0)
    stats = engine.stats()
    print(f"  16 requests -> met={stats['met']} missed={stats['missed']} "
          f"batches={stats['batches']} forwards={stats['forwards']}")
    print(f"  first result: class {reqs[0].result}, "
          f"latency {reqs[0].done_at - reqs[0].arrival:.1f}ut")


if __name__ == "__main__":
    part1_simulator()
    part2_serving()
