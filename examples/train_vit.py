"""End-to-end training driver: train a ViT classifier with the full
substrate (synthetic data pipeline, AdamW, checkpointing, auto-resume).

Smoke scale by default (CPU-friendly); pass --arch/--steps to scale up —
--arch deit-b --full trains the real 86M-parameter DeiT-B config.

Run:  PYTHONPATH=src python examples/train_vit.py --steps 200
"""
import argparse

from repro.configs import get_config, get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.launch import steps as S
from repro.training.train_loop import TrainLoopConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (big!)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_vit")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    shape = ShapeSpec("example", "train", img_res=cfg.img_res,
                      global_batch=args.batch)
    S.shapes_for(cfg)["example"] = shape
    try:
        cell = S.build_cell(args.arch, "example", cfg=cfg)
    finally:
        S.shapes_for(cfg).pop("example", None)

    print(f"training {cfg.name} for {args.steps} steps "
          f"(batch {args.batch}, ckpt every 50 to {args.ckpt_dir})")
    out = run(cell, TrainLoopConfig(total_steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=10))
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"done in {out['wall_s']:.1f}s — loss {first:.3f} -> {last:.3f}")
    print("re-run the same command to watch it auto-resume from the "
          "latest checkpoint")


if __name__ == "__main__":
    main()
