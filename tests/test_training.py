"""Training substrate tests: optimizer, data pipeline, compression, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.training.compression import (dequantize_int8, quantize_int8,
                                        roundtrip_with_feedback)
from repro.training.data import PrefetchIterator, SyntheticSource
from repro.training.elastic import replace_mesh, shrink_batch, surviving_mesh
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, schedule_lr)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, schedule="constant")
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params, cfg)

        def loss(p):
            return jnp.sum(jnp.square(p["w"]))

        for _ in range(100):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw_update(params, grads, state, cfg)
        assert float(loss(params)) < 0.1

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.zeros((4,))}
        state = init_opt_state(params, cfg)
        grads = {"w": jnp.full((4,), 100.0)}
        _, _, metrics = adamw_update(params, grads, state, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_bf16_state_dtype(self):
        cfg = AdamWConfig(state_dtype=jnp.bfloat16)
        params = {"w": jnp.zeros((4,), jnp.bfloat16)}
        state = init_opt_state(params, cfg)
        assert state.m["w"].dtype == jnp.bfloat16
        grads = {"w": jnp.ones((4,), jnp.bfloat16)}
        _, state, _ = adamw_update(params, grads, state, cfg)
        assert state.v["w"].dtype == jnp.bfloat16

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in
               (0, 5, 10, 50, 99)]
        assert lrs[0] < lrs[1] < lrs[2]          # warmup rising
        assert lrs[2] >= lrs[3] >= lrs[4]        # cosine falling
        assert lrs[4] < 0.05


class TestData:
    def test_deterministic_per_step(self):
        spec = {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32),
                "y": jax.ShapeDtypeStruct((4,), jnp.int32)}
        s = SyntheticSource(spec, seed=3)
        a, b = s.batch_at(7), s.batch_at(7)
        np.testing.assert_array_equal(a["x"], b["x"])
        c = s.batch_at(8)
        assert not np.array_equal(a["x"], c["x"])

    def test_prefetch_ordering(self):
        spec = {"x": jax.ShapeDtypeStruct((2,), jnp.float32)}
        it = PrefetchIterator(SyntheticSource(spec), start_step=5)
        try:
            steps = [next(it)[0] for _ in range(4)]
            assert steps == [5, 6, 7, 8]
        finally:
            it.close()


class TestCompression:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 1000))
    def test_int8_roundtrip_error_bound(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
        q, s = quantize_int8(x)
        err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
        assert float(err) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_preserves_mean_signal(self):
        """With feedback, the accumulated dequantized signal tracks the
        accumulated true gradient (bias-free compression)."""
        g = {"w": jnp.full((16,), 0.013)}
        resid = None
        total = jnp.zeros((16,))
        for _ in range(50):
            deq, resid = roundtrip_with_feedback(g, resid)
            total = total + deq["w"]
        np.testing.assert_allclose(np.asarray(total), 0.013 * 50,
                                   rtol=0.05)


class TestElastic:
    def test_surviving_mesh_shapes(self):
        n = len(jax.devices())
        mesh = surviving_mesh(n, model_parallel=1)
        assert mesh.size == n
        mesh2 = surviving_mesh(n, model_parallel=64)
        assert mesh2.size == n                # mp shrinks to fit

    def test_replace_mesh_and_shrink_batch(self):
        mesh = surviving_mesh(len(jax.devices()), 1)
        tree = {"w": jnp.arange(32.0).reshape(8, 4)}
        specs = {"w": (None, None)}
        placed = replace_mesh(tree, specs, mesh)
        np.testing.assert_array_equal(np.asarray(placed["w"]),
                                      np.asarray(tree["w"]))
        assert shrink_batch(256, old_dp=16, new_dp=12) == 192

    def test_failure_recovery_end_to_end(self, tmp_path):
        """Checkpoint under mesh A, 'lose' devices, restore under mesh B."""
        from repro.training import checkpoint as ckpt
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8))}
        ckpt.save_checkpoint(tmp_path, 10, tree)
        restored, _ = ckpt.restore_latest(tmp_path, tree)
        mesh = surviving_mesh(max(1, len(jax.devices()) // 2), 1)
        placed = replace_mesh(restored, {"w": (None, None)}, mesh)
        np.testing.assert_array_equal(np.asarray(placed["w"]),
                                      np.asarray(tree["w"]))
