"""Distribution tests: logical rules, divisibility fallback, a real
small-mesh lower+compile, and shard_map MoE equivalence.

Multi-device tests run in subprocesses because XLA locks the host device
count at first jax init (the main pytest process must stay at 1 device for
the smoke tests)."""
import json
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


class TestLogicalRules:
    def teardown_method(self):
        shd.clear_rules()

    def test_no_rules_noop(self):
        shd.clear_rules()
        import jax.numpy as jnp
        x = jnp.ones((4, 4))
        assert shd.hint(x, "dp", None) is x

    def test_logical_resolution(self):
        shd.set_rules(dp=("pod", "data"), tp="model")
        assert shd.logical("dp", None, "tp") == P(("pod", "data"), None, "model")
        assert shd.logical(None, "missing") == P(None, None)

    def test_rules_cleared(self):
        shd.set_rules(dp="data")
        shd.clear_rules()
        assert shd.get_rules() == {}
        assert shd.active_mesh() is None


def _run_subprocess(code: str) -> str:
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert res.returncode == 0, f"stderr:\n{res.stderr[-3000:]}"
    return res.stdout


import jax.sharding as _jax_sharding

# These subprocess tests build meshes with jax.sharding.AxisType
# (jax >= 0.4.31); skip cleanly on older installs instead of failing
# inside the subprocess.
requires_axis_type = pytest.mark.skipif(
    not hasattr(_jax_sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType")


@pytest.mark.slow
@requires_axis_type
class TestSmallMeshCompile:
    def test_dryrun_cell_on_8_devices(self):
        """A reduced LM cell lowers + compiles on a real 2x4 mesh with the
        full sharding-rule machinery (subprocess: needs 8 host devices)."""
        out = _run_subprocess("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax
            from jax.sharding import AxisType, NamedSharding
            from repro.configs import get_smoke_config
            from repro.configs.shapes import ShapeSpec
            from repro.launch import steps as S
            from repro.launch.mesh import install_rules
            from repro.launch.dryrun import _to_shardings

            cfg = get_smoke_config("gemma3-27b")
            S.shapes_for(cfg)["t"] = ShapeSpec("t", "train", seq_len=32,
                                               global_batch=4)
            cell = S.build_cell("gemma3-27b", "t", cfg=cfg)
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
            install_rules(mesh, cfg, 4)
            ins = _to_shardings(mesh, cell.arg_logical, cell.arg_specs)
            with mesh:
                compiled = jax.jit(cell.step_fn, in_shardings=ins
                                   ).lower(*cell.arg_specs).compile()
            cost = compiled.cost_analysis()
            print("OK", float((cost[0] if isinstance(cost, list) else
                               cost).get("flops", 0)) > 0)
        """)
        assert "OK True" in out

    def test_uneven_dim_replicated_not_errored(self):
        """_to_shardings drops axes that do not divide (e.g. a 1000-class
        head over a 16-way axis) instead of failing at jit."""
        out = _run_subprocess("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import AxisType
            from repro.distributed import sharding as shd
            from repro.launch.dryrun import _to_shardings
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
            shd.set_rules(mesh=mesh, dp="data", tp="model")
            specs = {"w": jax.ShapeDtypeStruct((10, 1001), jnp.float32)}
            logical = {"w": ("dp", "tp")}
            sh = _to_shardings(mesh, logical, specs)
            print("spec", sh["w"].spec)
        """)
        assert "spec PartitionSpec('data', None)" in out

    def test_shard_map_moe_matches_global(self):
        out = _run_subprocess("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import AxisType
            from repro.models import moe
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
            ks = jax.random.split(jax.random.PRNGKey(0), 5)
            T, d, E, f, k = 32, 16, 8, 24, 2
            x = jax.random.normal(ks[0], (T, d))
            rw = jax.random.normal(ks[1], (d, E)) * 0.1
            wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
            wu = jax.random.normal(ks[3], (E, d, f)) * 0.1
            wd = jax.random.normal(ks[4], (E, f, d)) * 0.1
            ref, _ = moe.moe_ffn(x, rw, wg, wu, wd, top_k=k,
                                 capacity_factor=8.0)
            with mesh:
                got, _ = jax.jit(lambda *a: moe.moe_ffn_sharded(
                    *a, top_k=k, capacity_factor=8.0, mesh=mesh,
                    dp_axes=("data",), model_axis="model", fsdp_axes="data",
                    expert_sharded=True))(x, rw, wg, wu, wd)
            err = float(np.abs(np.asarray(got) - np.asarray(ref)).max())
            print("maxdiff", err)
        """)
        assert float(out.split("maxdiff")[1]) < 1e-5


def _hlo_parser_matches_this_xla() -> bool:
    """The text cost model tracks a specific XLA HLO dialect; newer/older
    jaxlibs render fusions differently and the parser sees no flops.
    Runs at collection, so any probe failure means skip — never abort."""
    try:
        import jax
        import jax.numpy as jnp
        from repro.launch.hlo_cost import analyze_hlo
        c = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32),
            jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
        return analyze_hlo(c.as_text()).flops > 0
    except Exception:
        return False


@pytest.mark.skipif(not _hlo_parser_matches_this_xla(),
                    reason="installed jaxlib emits an HLO dialect "
                           "hlo_cost.analyze_hlo does not parse")
class TestHloCostModel:
    def test_loop_free_matches_cost_analysis(self):
        out = _run_subprocess("""
            import jax, jax.numpy as jnp
            from repro.launch.hlo_cost import analyze_hlo
            def g(x, w):
                return jnp.tanh(x @ w) @ w.T
            x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
            w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
            c = jax.jit(g).lower(x, w).compile()
            ca = c.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca
            mine = analyze_hlo(c.as_text())
            print("flops", mine.flops == ca.get("flops"),
                  "bytes", mine.bytes == ca.get("bytes accessed"))
        """)
        assert "flops True bytes True" in out

    def test_scan_trip_count_multiplied(self):
        out = _run_subprocess("""
            import jax, jax.numpy as jnp
            from repro.launch.hlo_cost import analyze_hlo
            def f(x, w):
                def body(h, wi):
                    return jnp.tanh(h @ wi), None
                return jax.lax.scan(body, x, w)[0]
            x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
            w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
            c = jax.jit(f).lower(x, w).compile()
            mine = analyze_hlo(c.as_text())
            print("flops", mine.flops, "expected", 7 * 2 * 16 * 64 * 64)
        """)
        _, flops, _, expected = out.split()
        assert float(flops) == float(expected)
