"""Fleet simulator vs the event-heap Orchestrator, and the Pallas
fleet-feasibility kernel vs its pure-jnp oracle.

Equivalence contract (DESIGN.md §5): per-request outcomes match the host
engine exactly — deterministic policies replay move-for-move, stochastic
policies under forwarding-trace replay.  The deterministic-pytest cases
here pin the contract on fixed workloads (they run without hypothesis);
the property test widens the net over random fleets when hypothesis is
installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_queue as jq
from repro.fleetsim import (DISCARDED, MET, SimParams, pack_requests,
                            simulate, simulate_fn, topology_arrays)
from repro.fleetsim.validate import run_validation
from repro.kernels import ops, ref
from repro.orchestration import Topology, UniformWorkload, get_workload

# a 3-node workload deep in overload: forwards, forced pushes and late
# completions all exercised (~20x the window's worth of work per node)
HOT = UniformWorkload([{"S1": 30, "S4": 30, "S5": 25, "S6": 25}] * 3,
                      window=1200.0, name="hot")
POLICIES = ("random", "power_of_two", "least_loaded", "round_robin",
            "batched_feasible")


# ---------------------------------------------------------------------------
# host equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_matches_orchestrator_hot_fleet(policy):
    for seed in (0, 1):
        rep = run_validation(HOT, seed, policy=policy)
        assert rep.exact, (policy, seed, rep.row())
        for k in ("met_deadline", "processed", "forwards", "discarded"):
            assert rep.host[k] == rep.fleet[k], (policy, seed, k)


def test_matches_orchestrator_discard_variant():
    rep = run_validation(HOT, 0, policy="random", discard_on_exhaust=True)
    assert rep.exact and rep.fleet["discarded"] > 0, rep.row()


def test_matches_orchestrator_heterogeneous_ring():
    topo = Topology.ring(3, speeds=[1.0, 2.0, 0.5])
    rep = run_validation(HOT, 0, policy="round_robin", topology=topo)
    assert rep.exact, rep.row()


def test_batched_feasible_scores_post_retire_state():
    """Regression: the fused event_select scoring must run on the
    POST-retire ledgers.  At t=12 the d_abs=18 block has completed (busy
    chain 5→10) but is not yet retired when the event is selected; scoring
    the stale ledger inflates pending work (21 - (12+5) < 5 → bogus
    infeasible) and forwards a request the host admits on the spot."""
    from repro.core.request import Request, Service
    from repro.orchestration import Workload

    class _Fixed(Workload):
        name = "stale-retire"
        n_nodes = 2

        def generate(self, seed):
            return self._finish([
                Request(service=Service("a", 1, "x", 5.0, 100.0),
                        arrival_time=0.0, origin_node=0),
                Request(service=Service("b", 1, "x", 5.0, 17.0),
                        arrival_time=1.0, origin_node=0),
                Request(service=Service("c", 1, "x", 5.0, 9.0),
                        arrival_time=12.0, origin_node=0),
            ])

    rep = run_validation(_Fixed(), 0, policy="batched_feasible",
                         topology=Topology.full_mesh(2))
    assert rep.exact, rep.row()
    assert rep.host["forwards"] == rep.fleet["forwards"] == 0
    # and the Pallas kernel path agrees with the reference path on it
    reqs, _, _ = pack_requests(_Fixed().generate(0))
    ta = topology_arrays(Topology.full_mesh(2))
    kw = dict(policy="batched_feasible", capacity=16, depth=16)
    a = simulate(reqs, ta, SimParams.make(0), use_pallas=False, **kw)
    b = simulate(reqs, ta, SimParams.make(0), use_pallas=True, **kw)
    assert np.array_equal(np.asarray(a.outcome), np.asarray(b.outcome))
    assert int(a.forwards) == int(b.forwards) == 0


@pytest.mark.parametrize("scenario", ["paper/scenario1"])
def test_matches_orchestrator_paper_scenario(scenario):
    """The acceptance contract on a real paper workload (seed 0): exact
    per-request outcome equality under trace replay (scenarios 2-3 are
    covered by `python -m repro.fleetsim.validate`; one scenario keeps the
    suite's runtime tolerable)."""
    rep = run_validation(scenario, 0, policy="random")
    assert rep.exact, rep.row()
    assert rep.fleet["forwards"] > 0


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle (interpret mode off-TPU — skips nothing, the
# kernel body lowers through the interpreter to plain XLA)
# ---------------------------------------------------------------------------
def _random_fleet(rng, K, N):
    leds = []
    frees = []
    for _ in range(K):
        led = jq.empty_ledger(N)
        free = rng.uniform(0, 50)
        for _ in range(rng.randrange(0, N + 2)):
            led, _ = jq.push(led, jnp.float32(rng.choice([5.0, 20.0, 44.0])),
                             jnp.float32(rng.uniform(10, 9000)),
                             jnp.float32(free))
        leds.append(led)
        frees.append(free)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *leds)
    return stacked, jnp.asarray(frees, jnp.float32)


@pytest.mark.parametrize("K,N", [(1, 8), (5, 16), (12, 32)])
def test_fleet_feasibility_kernel_matches_ref(K, N):
    import random
    rng = random.Random(K * 31 + N)
    stacked, frees = _random_fleet(rng, K, N)
    ps = jnp.asarray([rng.choice([5.0, 20.0, 44.0, 180.0])
                      for _ in range(K)], jnp.float32)
    for d in (30.0, 400.0, 8000.0):
        got_f, got_l = ops.fleet_feasibility(
            stacked.starts, stacked.ends, stacked.sizes, stacked.n, ps,
            jnp.float32(d), frees)
        want_f, want_l = ref.fleet_feasibility_ref(
            stacked.starts, stacked.ends, stacked.sizes, stacked.n, ps,
            jnp.float32(d), frees)
        base = jq.feasible_nodes(stacked, ps, jnp.float32(d), frees) \
            & (stacked.n < N)
        assert np.array_equal(np.asarray(got_f), np.asarray(want_f)), d
        assert np.array_equal(np.asarray(got_f), np.asarray(base)), d
        np.testing.assert_allclose(np.asarray(got_l), np.asarray(want_l),
                                   rtol=1e-6)


def test_fleet_feasibility_kernel_head_pointer_rows():
    """Retired-slot prefixes (-BIG/0 + head offset) give the same verdict
    as the equivalent compacted plain ledger."""
    led = jq.empty_ledger(16)
    for (p, d) in ((20.0, 100.0), (44.0, 400.0), (180.0, 9000.0)):
        led, _ = jq.push(led, jnp.float32(p), jnp.float32(d), jnp.float32(0.0))
    # head-pointer view: two retired slots in front
    h = 2
    starts = jnp.concatenate([jnp.full((h,), -jq.BIG), led.starts[:-h]])
    ends = jnp.concatenate([jnp.full((h,), -jq.BIG), led.ends[:-h]])
    sizes = jnp.concatenate([jnp.zeros((h,)), led.sizes[:-h]])
    for ps, d in ((5.0, 60.0), (44.0, 300.0), (180.0, 9000.0), (500.0, 200.0)):
        got, _ = ops.fleet_feasibility(
            starts[None], ends[None], sizes[None], led.n[None],
            jnp.float32(ps)[None], jnp.float32(d), jnp.zeros((1,)),
            jnp.array([h], jnp.int32))
        want = jq.feasible(led, jnp.float32(ps), jnp.float32(d),
                           jnp.float32(0.0))
        assert bool(got[0]) == bool(want), (ps, d)


def test_simulate_use_pallas_matches_ref_path():
    reqs, _, _ = pack_requests(HOT.generate(0))
    ta = topology_arrays(Topology.full_mesh(3))
    kw = dict(policy="batched_feasible", capacity=256, depth=128)
    a = simulate(reqs, ta, SimParams.make(0), use_pallas=False, **kw)
    b = simulate(reqs, ta, SimParams.make(0), use_pallas=True, **kw)
    assert np.array_equal(np.asarray(a.outcome), np.asarray(b.outcome))
    assert int(a.met_deadline) == int(b.met_deadline)


# ---------------------------------------------------------------------------
# jax_queue generalizations backing the fleet state
# ---------------------------------------------------------------------------
def test_admit_forced_tail_append_matches_host():
    from repro.core.block_queue import FastPreferentialQueue
    from repro.core.request import Request, Service
    host = FastPreferentialQueue()
    led = jq.empty_ledger(8)
    meta = (jnp.zeros((8,), jnp.int32),)
    svc = Service("s", 1, "x", 50.0, 60.0)
    for k, forced in enumerate([False, True, True]):
        r = Request(service=svc, arrival_time=0.0, origin_node=0)
        ok_host = host.push(r, 0.0, forced=forced)
        led, ok, wf, meta = jq.admit(led, jnp.float32(50.0), jnp.float32(60.0),
                                     jnp.float32(0.0), jnp.bool_(forced),
                                     meta=meta, meta_vals=(k,))
        assert bool(ok) == ok_host
        if ok_host and k > 0:
            assert bool(wf)                      # landed via the tail append
    n = int(led.n)
    np.testing.assert_allclose(np.asarray(led.ends[:n]),
                               [b.end for b in host.blocks], rtol=1e-6)
    # metadata rode the inserts: slot i holds the i-th admitted request
    assert list(np.asarray(meta[0][:n])) == [0, 1, 2]


def test_push_nodes_stacked():
    leds = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[jq.empty_ledger(8) for _ in range(3)])
    ps = jnp.array([10.0, 10.0, 1000.0], jnp.float32)
    ds = jnp.array([50.0, 50.0, 50.0], jnp.float32)
    out, ok, wf = jq.push_nodes(leds, ps, ds, jnp.zeros((3,), jnp.float32),
                                jnp.array([False, True, True]))
    assert list(np.asarray(ok)) == [True, True, True]
    assert list(np.asarray(wf)) == [False, False, True]   # infeasible+forced
    assert list(np.asarray(out.n)) == [1, 1, 1]


# ---------------------------------------------------------------------------
# vmap sweeps
# ---------------------------------------------------------------------------
def test_vmap_over_seeds_and_sla_scale():
    reqs, _, _ = pack_requests(HOT.generate(0))
    ta = topology_arrays(Topology.full_mesh(3))
    reqs = type(reqs)(*(jnp.asarray(a) for a in reqs))
    ta = type(ta)(*(jnp.asarray(a) for a in ta))
    R = reqs.arrival.shape[0]
    tgt = jnp.full((R, 2), -1, jnp.int32)
    run = simulate_fn(policy="random", capacity=256, depth=128)
    grid = jax.vmap(jax.vmap(run, in_axes=(None, None, SimParams(None, 0), None)),
                    in_axes=(None, None, SimParams(0, None), None))
    params = SimParams(seed=jnp.arange(3, dtype=jnp.int32),
                       sla_scale=jnp.array([0.5, 1.0, 2.0], jnp.float32))
    m = grid(reqs, ta, params, tgt)
    assert m.met_deadline.shape == (3, 3)
    met = np.asarray(m.met_deadline)
    # looser SLAs can only help: met rate monotone in sla_scale per seed
    assert (met[:, 0] <= met[:, 2]).all()
    assert int(m.overflow.max()) == 0
    assert int(m.window_saturation.max()) == 0


def test_single_node_degenerate_and_sla_scale_effect():
    wl = UniformWorkload([{"S6": 30}], window=100.0, name="solo")
    reqs, _, _ = pack_requests(wl.generate(0))
    ta = topology_arrays(Topology.full_mesh(1))
    m = simulate(reqs, ta, SimParams.make(0), policy="random", capacity=64)
    assert int(m.forwards) == 0                    # nowhere to forward
    assert int(m.processed) == 30                  # forced pushes run late
    tight = simulate(reqs, ta, SimParams(jnp.int32(0), jnp.float32(1e-3)),
                     policy="random", capacity=64)
    assert int(tight.met_deadline) < int(m.met_deadline)


def test_undersized_window_is_flagged_not_silent():
    """A depth window smaller than the real queue depth must surface in
    window_saturation (admission verdicts may diverge from the host's
    unbounded queue there) — never pass silently."""
    reqs, _, _ = pack_requests(HOT.generate(0))
    ta = topology_arrays(Topology.full_mesh(3))
    tiny = simulate(reqs, ta, SimParams.make(0), policy="least_loaded",
                    capacity=256, depth=8)
    assert int(tiny.window_saturation) > 0
    sized = simulate(reqs, ta, SimParams.make(0), policy="least_loaded",
                     capacity=256, depth=128)
    assert int(sized.window_saturation) == 0


def test_undersized_event_plane_is_flagged_not_silent():
    """The event-time scan's two sizing knobs — scan length (max_events)
    and the in-flight re-arrival buffer (event_buf) — must surface any
    shortfall in metrics.event_overflow, never pass silently."""
    from repro.fleetsim import NetParams, event_bound
    reqs, _, _ = pack_requests(HOT.generate(0))
    ta = topology_arrays(Topology.full_mesh(3))
    R = reqs.arrival.shape[0]
    kw = dict(policy="least_loaded", capacity=256, depth=128)
    full = simulate(reqs, ta, SimParams.make(0), **kw)
    assert int(full.event_overflow) == 0
    assert event_bound(R, 2) == 3 * R
    # scan shorter than the event stream: leftovers are counted
    short = simulate(reqs, ta, SimParams.make(0), max_events=R // 2, **kw)
    assert int(short.event_overflow) > 0
    # a 1-slot buffer under a priced network (25 UT wire vs ~3.6 UT
    # arrival gaps => many referrals in flight at once): drops counted
    net = NetParams.uniform(3, 25.0)
    tight = simulate(reqs, ta, SimParams.make(0), net=net, event_buf=1, **kw)
    assert int(tight.event_overflow) > 0
    sized = simulate(reqs, ta, SimParams.make(0), net=net, **kw)
    assert int(sized.event_overflow) == 0


def test_event_scan_orders_rearrivals_by_time_not_source():
    """Direct check of the deferred-re-arrival contract: with a uniform
    50 UT wire, a request forwarded at t re-arrives at t+50 — after every
    fresh arrival in (t, t+50) — and its per-request transfer_used
    records exactly the wire time paid."""
    from repro.fleetsim import NetParams
    reqs, _, _ = pack_requests(HOT.generate(0))
    ta = topology_arrays(Topology.full_mesh(3))
    m = simulate(reqs, ta, SimParams.make(0), policy="round_robin",
                 capacity=256, depth=128, net=NetParams.uniform(3, 50.0))
    nfwd = np.asarray(m.forwards_used)
    assert nfwd.sum() > 0
    np.testing.assert_allclose(np.asarray(m.transfer_used), nfwd * 50.0,
                               rtol=1e-6)
    assert float(m.transfer_time) == pytest.approx(float(nfwd.sum() * 50.0),
                                                   rel=1e-6)
    # a forwarded request can never complete before its wire-delayed
    # arrival plus its own work
    completion = np.asarray(m.completion)
    done = completion > 0
    floor = (np.asarray(reqs.arrival) + nfwd * 50.0 + np.asarray(reqs.proc))
    assert (completion[done] >= floor[done] - 1e-2).all()


def test_workload_to_arrays_round_trip():
    reqs, names = get_workload("paper/scenario1").to_arrays(0)
    assert reqs.arrival.shape == (6000,)
    assert names == ("S1", "S2", "S3", "S4", "S5", "S6")
    assert (np.diff(reqs.arrival) >= 0).all()      # arrival-sorted
    assert reqs.origin.max() == 2


# ---------------------------------------------------------------------------
# property-based equivalence (hypothesis optional, as elsewhere)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                # pragma: no cover
    _HYP = False

if _HYP:
    # times quantized to halves: epsilon-scale f32-vs-f64 boundary ties are
    # precision artifacts, not dynamics (same convention as test_jax_queue)
    svc_mix = st.lists(
        st.tuples(st.sampled_from([5.0, 20.0, 44.0, 180.0]),
                  st.sampled_from([60.0, 400.0, 4000.0]),
                  st.integers(0, 2000).map(lambda i: i / 2.0)),
        min_size=5, max_size=40)

    @settings(max_examples=25, deadline=None)
    @given(svc_mix, st.integers(1, 4), st.integers(0, 2 ** 20),
           st.sampled_from(["random", "round_robin", "least_loaded",
                            "batched_feasible"]))
    def test_property_random_fleets_match_host(mix, n_nodes, seed, policy):
        import random as pyrandom
        from repro.core.request import Request, Service
        from repro.orchestration import Workload

        class _Fixed(Workload):
            name = "prop"
            n_nodes_ = n_nodes

            def __init__(self):
                self.n_nodes = n_nodes

            def generate(self, s):
                rng = pyrandom.Random(s)
                reqs = [Request(service=Service(f"p{p}d{d}", 1, "x", p, d),
                                arrival_time=t, origin_node=rng.randrange(n_nodes))
                        for (p, d, t) in mix]
                return self._finish(reqs)

        rep = run_validation(_Fixed(), seed, policy=policy)
        assert rep.exact, (policy, seed, rep.row())
