"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per assignment: sweep shapes/dtypes and assert_allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

TOLS = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _allclose(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **TOLS[dtype])


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64),        # MHA, single block
    (2, 256, 4, 2, 64),        # GQA 2:1, two kv blocks
    (1, 384, 8, 2, 128),       # GQA 4:1, non-128 seq multiple handled by pad
    (2, 100, 4, 1, 80),        # MQA, ragged seq + non-128 head dim (padded)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, KV, D, dtype, causal):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(k1, (B, S, H, D), jnp.float32)).astype(dtype)
    k = (jax.random.normal(k2, (B, S, KV, D), jnp.float32)).astype(dtype)
    v = (jax.random.normal(k3, (B, S, KV, D), jnp.float32)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    _allclose(got, want, dtype)


@pytest.mark.parametrize("window", [16, 64, 1024])
def test_flash_attention_sliding_window(window):
    B, S, H, KV, D = 1, 256, 4, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (B, S, H, D))
    k = jax.random.normal(keys[1], (B, S, KV, D))
    v = jax.random.normal(keys[2], (B, S, KV, D))
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    _allclose(got, want, jnp.float32)


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's chunked XLA attention path."""
    from repro.models.attention import attention
    B, S, H, KV, D = 2, 256, 4, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (B, S, H, D))
    k = jax.random.normal(keys[1], (B, S, KV, D))
    v = jax.random.normal(keys[2], (B, S, KV, D))
    got = ops.flash_attention(q, k, v, causal=True)
    want = attention(q, k, v, causal=True, impl="chunked", q_chunk=64)
    _allclose(got, want, jnp.float32)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 96, 200, 256]),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]), st.booleans())
def test_flash_attention_property(B, S, HKV, causal):
    H, KV = HKV
    D = 64
    keys = jax.random.split(jax.random.PRNGKey(S * 7 + B), 3)
    q = jax.random.normal(keys[0], (B, S, H, D))
    k = jax.random.normal(keys[1], (B, S, KV, D))
    v = jax.random.normal(keys[2], (B, S, KV, D))
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    _allclose(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,d", [(8, 64), (300, 128), (1024, 512), (7, 7168)])
def test_rmsnorm_matches_ref(N, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (N, d), jnp.float32).astype(dtype)
    s = (jax.random.normal(k2, (d,), jnp.float32) * 0.1).astype(dtype)
    got = ops.rmsnorm(x, s)
    want = ref.rmsnorm_ref(x, s)
    _allclose(got, want, dtype)


def test_rmsnorm_matches_model_norm():
    from repro.models.common import rms_norm
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 4, 256))
    s = jax.random.normal(jax.random.PRNGKey(4), (256,)) * 0.1
    _allclose(ops.rmsnorm(x, s), rms_norm(x, s), jnp.float32)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 500), st.sampled_from([32, 128, 384]))
def test_rmsnorm_property(N, d):
    x = jax.random.normal(jax.random.PRNGKey(N * 31 + d), (N, d))
    s = jnp.zeros((d,))
    got = ops.rmsnorm(x, s)
    # unit-RMS property: each output row has RMS ~= 1 (for zero scale offset)
    rms = jnp.sqrt(jnp.mean(jnp.square(got), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# moe grouped gemm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,d,f", [
    (4, 64, 128, 256),
    (8, 100, 64, 96),          # ragged C/f (padding path)
    (2, 256, 512, 128),
])
def test_moe_gemm_matches_ref(E, C, d, f, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = (jax.random.normal(k1, (E, C, d), jnp.float32) * 0.1).astype(dtype)
    w = (jax.random.normal(k2, (E, d, f), jnp.float32) * 0.1).astype(dtype)
    got = ops.moe_gemm(x, w)
    want = ref.moe_gemm_ref(x, w)
    _allclose(got, want, dtype)


def test_moe_gemm_is_blockwise_independent():
    """Each expert's output only depends on its own inputs."""
    E, C, d, f = 4, 32, 64, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (E, C, d))
    w = jax.random.normal(jax.random.PRNGKey(2), (E, d, f))
    base = ops.moe_gemm(x, w)
    x2 = x.at[2].set(0.0)
    out = ops.moe_gemm(x2, w)
    np.testing.assert_allclose(np.asarray(out[2]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(base[0]),
                               rtol=1e-6)
