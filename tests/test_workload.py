"""Workload generators + scenario registry: seed determinism, registry
counts, paper-scenario equivalence, Poisson/diurnal/trace processes."""
import collections

import pytest

from repro.core.request import SERVICES
from repro.core.scenarios import (DEFAULT_ARRIVAL_WINDOW, SCENARIOS,
                                  generate_requests)
from repro.orchestration import (DiurnalWorkload, PoissonWorkload,
                                 TraceWorkload, UniformWorkload,
                                 available_workloads, dump_trace,
                                 get_workload, register_workload)


def per_node_service_counts(requests):
    counts = collections.Counter()
    for r in requests:
        counts[(r.origin_node, r.service.name)] += 1
    return counts


class TestRegistry:
    def test_paper_scenarios_registered(self):
        names = available_workloads()
        for s in (1, 2, 3):
            assert f"paper/scenario{s}" in names

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError):
            get_workload("paper/scenario99")

    def test_duplicate_registration_raises(self):
        register_workload("t/dup", lambda: UniformWorkload([{"S3": 1}]))
        with pytest.raises(ValueError):
            register_workload("t/dup", lambda: UniformWorkload([{"S3": 1}]))
        register_workload("t/dup", lambda: UniformWorkload([{"S3": 2}]),
                          overwrite=True)

    @pytest.mark.parametrize("scenario", [1, 2, 3])
    def test_counts_match_registry(self, scenario):
        """Per-(node, service) request counts equal the Table II registry."""
        wl = get_workload(f"paper/scenario{scenario}")
        counts = per_node_service_counts(wl.generate(seed=5))
        for node_idx, svc_counts in enumerate(SCENARIOS[scenario]):
            for sname, want in svc_counts.items():
                assert counts[(node_idx, sname)] == want

    @pytest.mark.parametrize("scenario", [1, 2, 3])
    def test_reproduces_generate_requests(self, scenario):
        """The registered paper workloads generate the exact stream the
        legacy generate_requests produced (golden-path compatibility)."""
        wl = get_workload(f"paper/scenario{scenario}")
        a = wl.generate(seed=3)
        b = generate_requests(scenario, seed=3, arrival_window=DEFAULT_ARRIVAL_WINDOW)
        assert [(r.arrival_time, r.service.name, r.origin_node) for r in a] == \
               [(r.arrival_time, r.service.name, r.origin_node) for r in b]


class TestSeedDeterminism:
    @pytest.mark.parametrize("factory", [
        lambda: UniformWorkload([{"S1": 30, "S3": 50}, {"S2": 20}],
                                window=500.0, name="u"),
        lambda: PoissonWorkload([{"S1": 0.05, "S3": 0.1}, {"S2": 0.02}],
                                horizon=500.0, name="p"),
        lambda: DiurnalWorkload([{"S1": 30, "S3": 50}, {"S2": 20}],
                                window=500.0, peaks=3, name="d"),
    ], ids=["uniform", "poisson", "diurnal"])
    def test_same_seed_same_stream(self, factory):
        a = factory().generate(seed=11)
        b = factory().generate(seed=11)
        c = factory().generate(seed=12)
        key = lambda rs: [(r.arrival_time, r.service.name, r.origin_node)
                          for r in rs]
        assert key(a) == key(b)
        assert key(a) != key(c)

    def test_stream_stable_across_hash_randomization(self):
        """Custom (str-keyed) workloads must not depend on PYTHONHASHSEED —
        str rng seeds hash via sha512, unlike str-bearing tuple hashes."""
        import os
        import subprocess
        import sys
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        code = (
            "from repro.orchestration import (DiurnalWorkload, "
            "PoissonWorkload, UniformWorkload)\n"
            "for wl in (UniformWorkload([{'S3': 4}], window=100.0, name='u'),\n"
            "           PoissonWorkload([{'S3': 0.1}], horizon=100.0),\n"
            "           DiurnalWorkload([{'S3': 4}], window=100.0)):\n"
            "    print([round(r.arrival_time, 9) for r in wl.generate(0)])\n"
        )
        outs = set()
        for hashseed in ("1", "2"):
            env = dict(os.environ,
                       PYTHONPATH=os.path.abspath(src),
                       PYTHONHASHSEED=hashseed)
            res = subprocess.run(
                [sys.executable, "-c", code], capture_output=True, text=True,
                env=env)
            assert res.returncode == 0, res.stderr
            outs.add(res.stdout)
        assert len(outs) == 1, "arrival streams vary with PYTHONHASHSEED"

    def test_sorted_by_arrival(self):
        reqs = PoissonWorkload([{"S3": 0.2}], horizon=300.0).generate(seed=0)
        times = [r.arrival_time for r in reqs]
        assert times == sorted(times)


class TestPoisson:
    def test_from_counts_matches_expected_volume(self):
        counts = [{"S3": 400}, {"S6": 200}]
        wl = PoissonWorkload.from_counts(counts, horizon=1000.0)
        n = len(wl.generate(seed=0))
        assert 450 <= n <= 750          # ~600 expected, Poisson spread

    def test_respects_horizon_and_nodes(self):
        wl = PoissonWorkload([{"S3": 0.3}, {"S6": 0.3}], horizon=200.0)
        reqs = wl.generate(seed=1)
        assert wl.n_nodes == 2
        assert all(0 < r.arrival_time <= 200.0 for r in reqs)
        assert {r.origin_node for r in reqs} == {0, 1}


class TestDiurnal:
    def test_counts_exact_and_peaked(self):
        wl = DiurnalWorkload([{"S3": 4000}], window=1000.0, peaks=1,
                             amplitude=1.0)
        reqs = wl.generate(seed=2)
        assert len(reqs) == 4000
        # intensity 1 + sin(2*pi*t/W): first half-window holds the peak
        first_half = sum(1 for r in reqs if r.arrival_time < 500.0)
        assert first_half > 0.6 * len(reqs)

    def test_amplitude_validated(self):
        with pytest.raises(ValueError):
            DiurnalWorkload([{"S3": 1}], amplitude=1.5)


class TestTrace:
    def test_roundtrip(self, tmp_path):
        src = UniformWorkload([{"S1": 5, "S4": 3}, {"S2": 4}],
                              window=100.0, name="rt").generate(seed=0)
        path = tmp_path / "trace.jsonl"
        dump_trace(src, str(path))
        replay = TraceWorkload(str(path)).generate()
        key = lambda rs: [(r.arrival_time, r.service.name, r.origin_node)
                          for r in rs]
        assert key(replay) == key(src)
        assert TraceWorkload(str(path)).n_nodes == 2

    def test_unknown_service_rejected(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"service": "S99", "arrival_time": 1.0, "node": 0}\n')
        with pytest.raises(ValueError):
            TraceWorkload(str(path))

    def test_total_requests_helper(self):
        wl = UniformWorkload([{"S1": 7}], window=10.0, name="tt")
        assert wl.total_requests() == 7
        assert SERVICES["S1"].proc_time == 180.0
