"""Property tests: the chunked (online-softmax) attention path is
numerically equivalent to the naive oracle across GQA ratios, windows,
offsets and ragged lengths — this is the path every full-scale dry-run
lowers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.models.attention import (apply_rope, attention_chunked,
                                    attention_decode, attention_naive)


def _qkv(B, S, H, KV, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, KV, D)),
            jax.random.normal(ks[2], (B, S, KV, D)))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([33, 64, 100, 200]),       # ragged lengths
       st.sampled_from([(4, 4), (4, 2), (6, 2), (8, 1)]),
       st.booleans(),
       st.sampled_from([None, 16, 64]),
       st.sampled_from([16, 32, 64]))
def test_chunked_matches_naive(S, HKV, causal, window, chunk):
    H, KV = HKV
    q, k, v = _qkv(2, S, H, KV, 32, seed=S * 7 + H)
    want = attention_naive(q, k, v, causal=causal, window=window)
    got = attention_chunked(q, k, v, causal=causal, window=window,
                            q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_q_offset_matches_suffix_of_full():
    """Chunked attention with q_offset equals the suffix of full attention
    (the prefill-continuation contract)."""
    S, H, KV, D = 96, 4, 2, 32
    q, k, v = _qkv(1, S, H, KV, D, seed=3)
    full = attention_naive(q, k, v, causal=True)
    tail = attention_chunked(q[:, 64:], k, v, causal=True, q_chunk=16,
                             kv_chunk=32, q_offset=64)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 64:]),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_attention_last_token():
    S, H, KV, D = 40, 4, 2, 32
    q, k, v = _qkv(2, S, H, KV, D, seed=9)
    full = attention_naive(q, k, v, causal=True)
    # cache padded beyond the valid length
    pad = 8
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    got = attention_decode(q[:, -1:], kc, vc, cache_len=S)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_window_matches_windowed_attention():
    S, H, KV, D, W = 64, 4, 2, 32, 16
    q, k, v = _qkv(1, S, H, KV, D, seed=11)
    full = attention_naive(q, k, v, causal=True, window=W)
    got = attention_decode(q[:, -1:], k, v, cache_len=S, window=W)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    D = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))

    def score(qpos, kpos):
        qr = apply_rope(q, jnp.array([qpos]))
        kr = apply_rope(k, jnp.array([kpos]))
        return float(jnp.sum(qr * kr))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(7, 0) == pytest.approx(score(507, 500), rel=1e-4)


def test_gqa_reduces_to_mha_when_kv_repeated():
    """GQA with repeated KV heads equals MHA with those heads."""
    B, S, KV, G, D = 1, 24, 2, 3, 16
    H = KV * G
    q, k, v = _qkv(B, S, H, KV, D, seed=4)
    gqa = attention_naive(q, k, v, causal=True)
    k_rep = jnp.repeat(k, G, axis=2)
    v_rep = jnp.repeat(v, G, axis=2)
    mha = attention_naive(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha),
                               rtol=1e-5, atol=1e-5)
