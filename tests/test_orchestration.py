"""Unified orchestration core: golden equivalence with the pre-refactor
simulator, topology semantics, heterogeneous speeds, hooks, and the
synchronous place() helper."""
import heapq
import itertools
import json
import os
import random
import statistics

import pytest

from repro.core.block_queue import FastPreferentialQueue
from repro.core.node import MECNode
from repro.core.queues import FIFOQueue
from repro.core.request import SERVICES, Request
from repro.core.scenarios import SCENARIOS, generate_requests
from repro.core.simulator import SimConfig, make_queue, run_simulation
from repro.orchestration import (Hooks, Orchestrator, Router, Topology,
                                 UniformWorkload, place)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden_simulator.json")


# ---------------------------------------------------------------------------
# The pre-refactor event loop, verbatim (modulo the process-stable forwarding
# rng, shared with the adapter).  The adapter-backed run_simulation must be
# indistinguishable from this on the paper's experiment space.
# ---------------------------------------------------------------------------
_ARRIVAL, _COMPLETE = 0, 1


def _legacy_run_simulation(config: SimConfig):
    n_nodes = len(SCENARIOS[config.scenario])
    nodes = [MECNode(i, make_queue(config.queue)) for i in range(n_nodes)]
    fwd_rng = random.Random(f"forwarding:{config.seed}")

    requests = generate_requests(config.scenario, config.seed,
                                 config.arrival_window)
    seq = itertools.count()
    heap = []
    for req in requests:
        heapq.heappush(heap, (req.arrival_time, next(seq), _ARRIVAL, req,
                              nodes[req.origin_node]))

    forwards = discarded = 0
    completed = []

    def dispatch(node, now):
        req = node.start_next(now)
        if req is not None:
            heapq.heappush(heap, (node.busy_until, next(seq), _COMPLETE, req,
                                  node))

    while heap:
        now, _, kind, req, node = heapq.heappop(heap)
        if kind == _COMPLETE:
            node.complete(now)
            completed.append(req)
            dispatch(node, now)
            continue
        node.metrics.received += 1
        exhausted = req.forwards >= config.max_forwards
        forced = exhausted and not config.discard_on_exhaust
        if node.try_admit(req, now, forced=forced):
            dispatch(node, now)
        elif exhausted:
            discarded += 1
        else:
            req.forwards += 1
            forwards += 1
            node.metrics.forwards_out += 1
            target = fwd_rng.choice(
                [n for n in nodes if n.node_id != node.node_id])
            heapq.heappush(heap, (now + config.forward_delay, next(seq),
                                  _ARRIVAL, req, target))

    met = sum(1 for r in completed if r.met_deadline)
    resp = [r.completion_time - r.arrival_time for r in completed
            if r.completion_time is not None]
    return dict(total_requests=len(requests), processed=len(completed),
                met_deadline=met, forwards=forwards, discarded=discarded,
                mean_response_time=statistics.fmean(resp) if resp else 0.0,
                per_node_forwards=[n.metrics.forwards_out for n in nodes])


GOLDEN_GRID = [(sc, q, seed) for sc in (1, 2, 3)
               for q in ("fifo", "preferential", "edf") for seed in (0, 1)]


class TestGoldenEquivalence:
    @pytest.mark.parametrize("scenario,queue,seed", GOLDEN_GRID)
    def test_adapter_matches_legacy_loop(self, scenario, queue, seed):
        cfg = SimConfig(scenario=scenario, queue=queue, seed=seed)
        legacy = _legacy_run_simulation(cfg)
        res = run_simulation(cfg)
        assert res.total_requests == legacy["total_requests"]
        assert res.processed == legacy["processed"]
        assert res.met_deadline == legacy["met_deadline"]
        assert res.forwards == legacy["forwards"]
        assert res.discarded == legacy["discarded"]
        assert res.per_node_forwards == legacy["per_node_forwards"]
        assert res.mean_response_time == pytest.approx(
            legacy["mean_response_time"], rel=1e-12)

    def test_pinned_golden_values(self):
        """Cross-process / cross-version regression guard (stable rng)."""
        with open(GOLDEN_PATH) as f:
            golden = json.load(f)
        for scenario, queue, seed in GOLDEN_GRID:
            g = golden[f"{scenario}-{queue}-{seed}"]
            r = run_simulation(SimConfig(scenario=scenario, queue=queue,
                                         seed=seed))
            assert r.met_deadline == g["met_deadline"], (scenario, queue, seed)
            assert r.forwards == g["forwards"]
            assert r.discarded == g["discarded"]
            assert r.per_node_forwards == g["per_node_forwards"]
            assert r.mean_response_time == pytest.approx(
                g["mean_response_time"], rel=1e-9)


class TestTopology:
    def test_full_mesh(self):
        t = Topology.full_mesh(4)
        assert t.neighbors(0) == (1, 2, 3)
        assert t.neighbors(2) == (0, 1, 3)
        assert t.homogeneous

    def test_ring(self):
        t = Topology.ring(5)
        assert t.neighbors(0) == (1, 4)
        assert t.neighbors(3) == (2, 4)
        assert all(t.degree(i) == 2 for i in range(5))

    def test_star(self):
        t = Topology.star(4, hub=0)
        assert t.neighbors(0) == (1, 2, 3)
        assert t.neighbors(1) == (0,)

    def test_two_tier(self):
        t = Topology.two_tier(3, n_cloud=2, cloud_speed=4.0)
        assert t.n_nodes == 5
        assert t.neighbors(0) == (3, 4)          # edge -> clouds only
        assert t.neighbors(3) == (0, 1, 2, 4)    # cloud -> edges + peer
        assert t.speed(0) == 1.0 and t.speed(4) == 4.0
        assert not t.homogeneous

    def test_validation(self):
        with pytest.raises(ValueError):
            Topology(3, speeds=[1.0, 2.0])
        with pytest.raises(ValueError):
            Topology(2, edges=[(0, 5)])
        with pytest.raises(ValueError):
            Topology(2, speeds=[1.0, -1.0])


def _small_workload(n_nodes, per_node=40):
    counts = [{"S3": per_node, "S6": per_node} for _ in range(n_nodes)]
    return UniformWorkload(counts, window=2000.0, name="t", seed_key="t")


class TestOrchestrator:
    def test_ring_topology_runs_end_to_end(self):
        topo = Topology.ring(4)
        orch = Orchestrator(topo, FastPreferentialQueue, Router(topo, seed=1))
        res = orch.run(_small_workload(4).generate(seed=0))
        assert res.processed == res.total_requests
        assert res.discarded == 0
        assert set(res.per_service) == {"S3", "S6"}
        assert sum(s.processed for s in res.per_service.values()) == res.processed

    def test_forwards_respect_topology(self):
        """On a ring every forward must land on an adjacent node."""
        topo = Topology.ring(5)
        hops = []
        hooks = Hooks(on_forward=lambda req, src, dst, now:
                      hops.append((src.node_id, dst.node_id)))
        orch = Orchestrator(topo, FIFOQueue, Router(topo, seed=3),
                            hooks=hooks)
        orch.run(_small_workload(5, per_node=400).generate(seed=0))
        assert hops, "expected some forwarding under this load"
        for src, dst in hops:
            assert dst in topo.neighbors(src)

    def test_heterogeneous_speed_scales_proc_time(self):
        """A single 2x node finishes the same workload in half the time."""
        reqs = [Request(service=SERVICES["S1"], arrival_time=0.0,
                        origin_node=0) for _ in range(10)]
        slow = Orchestrator(Topology(1), FIFOQueue).run(list(reqs))
        reqs2 = [Request(service=SERVICES["S1"], arrival_time=0.0,
                         origin_node=0) for _ in range(10)]
        fast = Orchestrator(Topology(1, speeds=[2.0]), FIFOQueue).run(reqs2)
        assert fast.end_time == pytest.approx(slow.end_time / 2.0)
        assert fast.processed == slow.processed == 10

    def test_heterogeneous_does_not_mutate_caller_requests(self):
        req = Request(service=SERVICES["S3"], arrival_time=0.0, origin_node=0)
        res = Orchestrator(Topology(1, speeds=[4.0]), FIFOQueue).run([req])
        assert req.service.proc_time == SERVICES["S3"].proc_time
        assert req.completion_time == pytest.approx(5.0)   # 20 / 4x
        assert res.completed == [req]

    def test_faster_cloud_tier_improves_met_rate(self):
        wl = _small_workload(3, per_node=400)
        topo_flat = Topology.two_tier(2, n_cloud=1, cloud_speed=1.0)
        topo_fast = Topology.two_tier(2, n_cloud=1, cloud_speed=8.0)
        r_flat = Orchestrator(topo_flat, FastPreferentialQueue,
                              Router(topo_flat, seed=0)).run(wl.generate(0))
        r_fast = Orchestrator(topo_fast, FastPreferentialQueue,
                              Router(topo_fast, seed=0)).run(wl.generate(0))
        assert r_fast.met_deadline >= r_flat.met_deadline

    def test_batched_feasible_policy_end_to_end(self):
        pytest.importorskip("jax")
        topo = Topology.full_mesh(3)
        orch = Orchestrator(topo, FastPreferentialQueue,
                            Router(topo, "batched_feasible", seed=0))
        res = orch.run(_small_workload(3, per_node=400).generate(seed=0))
        assert res.processed == res.total_requests
        assert res.forwards > 0

    def test_discard_variant_and_hooks(self):
        topo = Topology.full_mesh(2)
        events = {"admit": 0, "forward": 0, "discard": 0, "complete": 0}
        hooks = Hooks(
            on_admit=lambda *a: events.__setitem__("admit", events["admit"] + 1),
            on_forward=lambda *a: events.__setitem__("forward", events["forward"] + 1),
            on_discard=lambda *a: events.__setitem__("discard", events["discard"] + 1),
            on_complete=lambda *a: events.__setitem__("complete", events["complete"] + 1),
        )
        orch = Orchestrator(topo, FIFOQueue, Router(topo, seed=0),
                            discard_on_exhaust=True, hooks=hooks)
        res = orch.run(_small_workload(2, per_node=500).generate(seed=0))
        assert res.discarded > 0
        assert events["discard"] == res.discarded
        assert events["admit"] == events["complete"] == res.processed
        assert events["forward"] == res.forwards
        assert res.processed + res.discarded == res.total_requests

    def test_isolated_node_forces_locally(self):
        """A node with no neighbors can never forward: everything is
        admitted locally (forced when infeasible), nothing is lost."""
        topo = Topology(2, edges=[])            # two isolated nodes
        orch = Orchestrator(topo, FIFOQueue)
        res = orch.run(_small_workload(2, per_node=50).generate(seed=0))
        assert res.forwards == 0
        assert res.processed == res.total_requests

    def test_run_is_reusable(self):
        """Node/queue state must not leak between runs of one instance."""
        topo = Topology.full_mesh(2)
        orch = Orchestrator(topo, FIFOQueue, Router(topo, seed=0))
        a = orch.run(_small_workload(2).generate(seed=0))
        b = orch.run(_small_workload(2).generate(seed=0))
        assert b.processed == b.total_requests
        assert b.met_deadline == a.met_deadline
        assert [m.received for m in b.per_node] == \
               [m.received for m in a.per_node]

    def test_mismatched_router_topology_rejected(self):
        with pytest.raises(ValueError):
            Orchestrator(Topology.ring(3), FIFOQueue,
                         Router(Topology.ring(4)))


class TestPlace:
    def _nodes(self, n):
        return [MECNode(i, FIFOQueue()) for i in range(n)]

    def test_admits_locally_when_feasible(self):
        nodes = self._nodes(2)
        topo = Topology.full_mesh(2)
        req = Request(service=SERVICES["S3"], arrival_time=0.0, origin_node=0)
        outcome, target = place(
            req, 0, nodes, Router(topo, seed=0), now=0.0, max_forwards=2,
            admit=lambda nd, r, t, forced: nd.try_admit(r, t, forced=forced))
        assert outcome == "admitted" and target is nodes[0]
        assert req.forwards == 0

    def test_forwards_then_forces(self):
        nodes = self._nodes(2)
        topo = Topology.full_mesh(2)
        router = Router(topo, seed=0)
        hops = []
        svc = SERVICES["S1"]                     # 180 UT work, 9000 deadline
        # saturate both nodes so a fresh request must exhaust its forwards
        for nd in nodes:
            for _ in range(60):
                nd.try_admit(Request(service=svc, arrival_time=0.0,
                                     origin_node=nd.node_id), 0.0, forced=True)
        req = Request(service=svc, arrival_time=0.0, origin_node=0)
        outcome, target = place(
            req, 0, nodes, router, now=0.0, max_forwards=2,
            admit=lambda nd, r, t, forced: nd.try_admit(r, t, forced=forced),
            on_forward=lambda r, s, d, t: hops.append((s.node_id, d.node_id)))
        assert outcome == "admitted"             # forced, never dropped
        assert req.forwards == 2 and len(hops) == 2

    def test_discard_on_exhaust(self):
        nodes = self._nodes(2)
        topo = Topology.full_mesh(2)
        svc = SERVICES["S1"]
        for nd in nodes:
            for _ in range(60):
                nd.try_admit(Request(service=svc, arrival_time=0.0,
                                     origin_node=nd.node_id), 0.0, forced=True)
        req = Request(service=svc, arrival_time=0.0, origin_node=0)
        outcome, _ = place(
            req, 0, nodes, Router(topo, seed=0), now=0.0, max_forwards=2,
            discard_on_exhaust=True,
            admit=lambda nd, r, t, forced: nd.try_admit(r, t, forced=forced))
        assert outcome == "discarded"
