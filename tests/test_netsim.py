"""netsim: wire-cost semantics, zero-cost equivalence with BOTH
orchestration cores, the fused link_cost kernel vs its oracle, and the
latency-never-helps property.

The equivalence contract (DESIGN.md §6): a zero-cost network
(``LinkModel.zero`` / ``NetParams.zero``) must reproduce the network-free
outputs of the event-heap Orchestrator and of ``fleetsim.simulate``
exactly — same per-request completions, same forwards, same everything.
A priced network then *consumes admission slack*: a referral can cause a
miss, which is the whole point of the subsystem.
"""
import math
import random

import numpy as np
import pytest

from repro.core.block_queue import FastPreferentialQueue
from repro.core.request import SERVICES, Request, Service
from repro.fleetsim import (NetParams, SimParams, pack_requests, simulate,
                            simulate_fn, topology_arrays)
from repro.fleetsim.validate import run_validation
from repro.netsim import (CellSite, LinkModel, RadioModel, RadioWorkload,
                          paper_campus)
from repro.netsim.radio import MIN_DEADLINE
from repro.orchestration import (ROUTER_POLICIES, Orchestrator, Router,
                                 Topology, UniformWorkload, Workload,
                                 get_workload)

jax = pytest.importorskip("jax")
import jax.numpy as jnp                                     # noqa: E402

from repro.core import jax_queue as jq                      # noqa: E402
from repro.kernels import ops, ref                          # noqa: E402

# the fleetsim test suite's overloaded 3-node workload: forwards, forced
# pushes and late completions all exercised
HOT = UniformWorkload([{"S1": 30, "S4": 30, "S5": 25, "S6": 25}] * 3,
                      window=1200.0, name="hot")


_uniform_net = NetParams.uniform


# ---------------------------------------------------------------------------
# LinkModel semantics
# ---------------------------------------------------------------------------
class TestLinkModel:
    def test_transfer_delay_is_latency_plus_serialization(self):
        topo = Topology.full_mesh(3)
        lm = LinkModel.uniform(topo, latency=5.0, bandwidth=2.0)
        # S1: 8 294 400 px * 3 B = 24.8832 MB -> 5 + 24.8832 / 2
        assert lm.transfer_delay(0, 1, SERVICES["S1"]) == pytest.approx(
            5.0 + 24.8832 / 2.0)
        # payload override table wins over the frame model
        lm2 = LinkModel.uniform(topo, 5.0, 2.0, payloads={"S1": 4.0})
        assert lm2.transfer_delay(0, 1, SERVICES["S1"]) == pytest.approx(7.0)

    def test_zero_model_prices_everything_at_zero(self):
        topo = Topology.ring(4)
        lm = LinkModel.zero(topo)
        assert lm.is_zero
        assert lm.transfer_delay(0, 1, SERVICES["S1"]) == 0.0
        assert lm.uplink_delay(SERVICES["S4"]) == 0.0
        np.testing.assert_array_equal(lm.net_params().latency,
                                      np.zeros((4, 4), np.float32))

    def test_non_edge_and_self_hops(self):
        topo = Topology.ring(4)                  # 0-1, 1-2, 2-3, 3-0
        lm = LinkModel.uniform(topo, 10.0, math.inf)
        assert lm.transfer_delay(0, 0, SERVICES["S3"]) == 0.0
        with pytest.raises(ValueError):
            lm.transfer_delay(0, 2, SERVICES["S3"])    # not a ring edge

    def test_preset_backhaul_pricing(self):
        topo = Topology.two_tier(2, n_cloud=1, cloud_speed=4.0)
        lm = LinkModel.preset(topo, "campus", cloud_nodes=[2])
        s3 = SERVICES["S3"]
        edge_cloud = lm.transfer_delay(0, 2, s3)
        assert edge_cloud > lm.uplink_delay(s3)
        # backhaul numbers, not LAN numbers
        assert edge_cloud == pytest.approx(
            30.0 + lm.payload_of(s3) / 0.3125)
        with pytest.raises(ValueError):
            LinkModel.preset(topo, "nope")

    def test_paper_campus_preset(self):
        topo, lm = paper_campus()
        assert topo.n_nodes == 3 and lm.name == "campus"
        assert not lm.is_zero
        net = lm.net_params()
        assert net.latency.shape == (3, 3)
        assert net.latency[0, 1] == pytest.approx(5.0)
        assert net.latency[0, 0] == 0.0

    def test_matrix_validation(self):
        topo = Topology.full_mesh(2)
        with pytest.raises(ValueError):
            LinkModel(topo, latency=[[0.0, 1.0]])          # bad shape
        with pytest.raises(ValueError):
            LinkModel(topo, latency=-1.0)
        with pytest.raises(ValueError):
            LinkModel(topo, bandwidth=0.0)


# ---------------------------------------------------------------------------
# zero-cost equivalence: the event-heap core
# ---------------------------------------------------------------------------
class TestOrchestratorZeroCost:
    @pytest.mark.parametrize("scenario", [1, 2, 3])
    def test_paper_scenarios_identical(self, scenario):
        """Zero-cost network == no network, per-request, on every paper
        scenario (the golden battery keeps holding with netsim wired in)."""
        wl = get_workload(f"paper/scenario{scenario}")
        topo = Topology.full_mesh(wl.n_nodes)
        base_reqs, net_reqs = wl.generate(0), wl.generate(0)
        base = Orchestrator(topo, FastPreferentialQueue,
                            Router(topo, seed=0)).run(base_reqs)
        netr = Orchestrator(topo, FastPreferentialQueue,
                            Router(topo, seed=0),
                            network=LinkModel.zero(topo)).run(net_reqs)
        assert (base.met_deadline, base.forwards, base.discarded,
                base.processed) == \
               (netr.met_deadline, netr.forwards, netr.discarded,
                netr.processed)
        assert netr.mean_response_time == base.mean_response_time
        assert netr.transfer_time == 0.0
        for a, b in zip(base_reqs, net_reqs):   # same generation order
            assert a.completion_time == b.completion_time
            assert a.served_by == b.served_by

    @pytest.mark.parametrize("policy", ROUTER_POLICIES)
    def test_policy_battery_identical(self, policy):
        topo = Topology.full_mesh(3)
        wl = HOT
        a_reqs, b_reqs = wl.generate(1), wl.generate(1)
        a = Orchestrator(topo, FastPreferentialQueue,
                         Router(topo, policy, seed=7)).run(a_reqs)
        b = Orchestrator(topo, FastPreferentialQueue,
                         Router(topo, policy, seed=7),
                         network=LinkModel.zero(topo)).run(b_reqs)
        assert a.met_deadline == b.met_deadline
        assert a.forwards == b.forwards
        for x, y in zip(a_reqs, b_reqs):
            assert x.completion_time == y.completion_time

    def test_network_topology_mismatch_rejected(self):
        with pytest.raises(ValueError):
            Orchestrator(Topology.full_mesh(3), FastPreferentialQueue,
                         network=LinkModel.zero(Topology.full_mesh(4)))

    def test_router_scoring_sees_forward_delay(self):
        """The orchestrator syncs forward_delay into the router alongside
        the network, so batched_feasible scores the true re-arrival —
        also when the caller already gave the router the same network."""
        topo = Topology.full_mesh(2)
        lm = LinkModel.zero(topo)
        router = Router(topo, "batched_feasible", seed=0)
        orch = Orchestrator(topo, FastPreferentialQueue, router,
                            forward_delay=50.0, network=lm)
        assert router.forward_delay == 50.0
        assert router.network is orch.network
        pre = Router(topo, "batched_feasible", seed=0, network=lm)
        Orchestrator(topo, FastPreferentialQueue, pre,
                     forward_delay=7.0, network=lm)
        assert pre.forward_delay == 7.0
        # conflicting link models are a configuration error, not a silent
        # scoring mismatch
        with pytest.raises(ValueError):
            Orchestrator(topo, FastPreferentialQueue,
                         Router(topo, network=LinkModel.zero(topo)),
                         network=lm)

    def test_priced_net_requires_payload(self):
        """simulate(net=...) must refuse payload-less RequestArrays
        instead of silently zeroing the serialization cost."""
        from repro.fleetsim import RequestArrays
        r = RequestArrays(
            arrival=np.array([0.0], np.float32),
            proc=np.array([5.0], np.float32),
            rel_deadline=np.array([50.0], np.float32),
            origin=np.array([0], np.int32),
            service=np.array([0], np.int32))
        ta = topology_arrays(Topology.full_mesh(2))
        with pytest.raises(ValueError, match="payload"):
            simulate(r, ta, SimParams.make(0), net=NetParams.zero(2),
                     capacity=16)
        # without a network the old 5-field form still simulates
        m = simulate(r, ta, SimParams.make(0), capacity=16)
        assert int(m.processed) == 1


# ---------------------------------------------------------------------------
# a referral can cause a miss (the paper's economics, restored)
# ---------------------------------------------------------------------------
def _two_node_contention():
    """Node 0's CPU is pinned by a long job; a tight request arriving next
    must refer to node 1 — free it costs nothing, priced it misses."""
    blocker = Service("blk", 1, "x", proc_time=100.0, deadline=105.0)
    tight = Service("tight", 1, "x", proc_time=10.0, deadline=30.0)
    return [Request(service=blocker, arrival_time=0.0, origin_node=0),
            Request(service=tight, arrival_time=1.0, origin_node=0)]


class TestReferralCost:
    def test_free_referral_meets_priced_referral_misses(self):
        topo = Topology.full_mesh(2)
        free = Orchestrator(topo, FastPreferentialQueue,
                            network=LinkModel.zero(topo))
        r_free = free.run(_two_node_contention())
        assert r_free.met_deadline == 2 and r_free.forwards == 1

        priced = Orchestrator(topo, FastPreferentialQueue,
                              network=LinkModel.uniform(topo, latency=25.0,
                                                        bandwidth=math.inf))
        reqs = _two_node_contention()
        r_priced = priced.run(reqs)
        # wire time ate the slack: arrival at node 1 is t=26, 10 UT of work
        # cannot finish by the absolute deadline 31 -> infeasible there too,
        # referred back, forced, late
        assert r_priced.met_deadline == 1
        assert r_priced.transfer_time > 0.0
        tight = [r for r in reqs if r.service.name == "tight"][0]
        assert tight.completion_time is not None
        assert not tight.met_deadline

    def test_host_and_fleet_agree_on_priced_sparse_chain(self):
        """Arrivals sparser than the wire delays: the simplest priced
        referral chain, cross-validated per request (the dense,
        interleaved variant follows in the next test)."""
        class _Fixed(Workload):
            name = "sparse-chain"
            n_nodes = 2

            def generate(self, seed):
                return self._finish(_two_node_contention())

        topo = Topology.full_mesh(2)
        lm = LinkModel.uniform(topo, latency=25.0, bandwidth=math.inf)
        rep = run_validation(_Fixed(), 0, policy="round_robin",
                             topology=topo, network=lm)
        assert rep.exact, rep.row()
        assert rep.fleet["forwards"] >= 1

    def test_interleaved_arrival_inside_priced_chain_is_exact(self):
        """The case the speculative-chain scan could NOT replay: a priced
        referral is in flight when another arrival lands at its target and
        eats the slack the source-step scoring saw.  The event-time scan
        defers the re-arrival to its true wire-delayed time, so the
        outcome (tight request: late) matches the heap per-request."""
        blocker = Service("blk", 1, "x", proc_time=100.0, deadline=105.0)
        tight = Service("tight", 1, "x", proc_time=10.0, deadline=40.0)
        interloper = Service("mid", 1, "x", proc_time=30.0, deadline=35.0)

        class _Fixed(Workload):
            name = "interleaved-chain"
            n_nodes = 2

            def generate(self, seed):
                return self._finish([
                    Request(service=blocker, arrival_time=0.0, origin_node=0),
                    Request(service=tight, arrival_time=1.0, origin_node=0),
                    # lands at node 1 at t=10 — before the tight request's
                    # wire-delayed re-arrival at t=26 — and occupies the
                    # CPU until t=40, past the tight deadline of t=41-10
                    Request(service=interloper, arrival_time=10.0,
                            origin_node=1),
                ])

        topo = Topology.full_mesh(2)
        lm = LinkModel.uniform(topo, latency=25.0, bandwidth=math.inf)
        rep = run_validation(_Fixed(), 0, policy="round_robin",
                             topology=topo, network=lm)
        assert rep.exact, rep.row()
        # the interleaving really bit: the tight request paid two referrals
        # and still ran late on both engines
        assert rep.fleet["forwards"] == rep.host["forwards"] == 2
        assert rep.fleet["met_deadline"] == rep.host["met_deadline"] == 2

    def test_validation_zero_net_exact_on_hot_fleet(self):
        """run_validation --net zero equivalent: the netsim machinery in
        both engines reproduces the free-network outcomes exactly."""
        topo = Topology.full_mesh(3)
        for policy in ("random", "batched_feasible"):
            rep = run_validation(HOT, 0, policy=policy, topology=topo,
                                 network=LinkModel.zero(topo))
            assert rep.exact, (policy, rep.row())

    @pytest.mark.parametrize("policy", ["random", "power_of_two",
                                        "least_loaded", "round_robin",
                                        "batched_feasible"])
    def test_priced_policy_battery_exact_under_campus(self, policy):
        """The §7 contract on a priced network: per-request (outcome,
        node, transfer_time) equality for every native policy — the
        deterministic ones move-for-move, the stochastic ones under
        forwarding-trace replay."""
        topo = Topology.full_mesh(3)
        rep = run_validation(HOT, 0, policy=policy, topology=topo,
                             network=LinkModel.campus(topo))
        assert rep.exact, (policy, rep.row())
        assert rep.fleet["forwards"] > 0
        assert rep.fleet["transfer_time"] > 0

    @pytest.mark.parametrize("profile", ["metro", "wan"])
    def test_priced_deterministic_policies_exact_on_heavy_profiles(
            self, profile):
        """Metro/WAN pricing (the expensive-referral regimes) for the two
        deterministic policies, which replay without a trace."""
        topo = Topology.full_mesh(3)
        lm = LinkModel.preset(topo, profile)
        for policy in ("round_robin", "batched_feasible"):
            rep = run_validation(HOT, 0, policy=policy, topology=topo,
                                 network=lm)
            assert rep.exact, (profile, policy, rep.row())


# ---------------------------------------------------------------------------
# fleetsim: zero-cost equivalence + the NetParams sweep axis
# ---------------------------------------------------------------------------
class TestFleetsimNet:
    @pytest.mark.parametrize("policy", ["random", "least_loaded",
                                        "round_robin", "batched_feasible"])
    def test_zero_net_outcomes_identical(self, policy):
        reqs, _, _ = pack_requests(HOT.generate(0))
        ta = topology_arrays(Topology.full_mesh(3))
        kw = dict(policy=policy, capacity=256, depth=128)
        a = simulate(reqs, ta, SimParams.make(0), **kw)
        b = simulate(reqs, ta, SimParams.make(0), net=NetParams.zero(3), **kw)
        assert np.array_equal(np.asarray(a.outcome), np.asarray(b.outcome))
        assert np.array_equal(np.asarray(a.completion),
                              np.asarray(b.completion))
        assert np.array_equal(np.asarray(a.served_by),
                              np.asarray(b.served_by))
        assert int(a.forwards) == int(b.forwards)

    def test_zero_net_pallas_path_identical(self):
        reqs, _, _ = pack_requests(HOT.generate(0))
        ta = topology_arrays(Topology.full_mesh(3))
        kw = dict(policy="batched_feasible", capacity=256, depth=128,
                  use_pallas=True)
        a = simulate(reqs, ta, SimParams.make(0), **kw)
        b = simulate(reqs, ta, SimParams.make(0), net=NetParams.zero(3), **kw)
        assert np.array_equal(np.asarray(a.outcome), np.asarray(b.outcome))

    def test_priced_net_pallas_matches_ref_path(self):
        """The fused link_cost kernel inside the scan == the jnp oracle
        inside the scan, outcome-for-outcome."""
        reqs, _, _ = pack_requests(HOT.generate(0))
        ta = topology_arrays(Topology.full_mesh(3))
        _, lm = paper_campus(3)
        kw = dict(policy="batched_feasible", capacity=256, depth=128,
                  net=lm.net_params())
        a = simulate(reqs, ta, SimParams.make(0), use_pallas=False, **kw)
        b = simulate(reqs, ta, SimParams.make(0), use_pallas=True, **kw)
        assert np.array_equal(np.asarray(a.outcome), np.asarray(b.outcome))
        assert int(a.met_deadline) == int(b.met_deadline)

    def test_latency_ladder_matches_host_rung_for_rung(self):
        """The event-time scan inherits the host's full priced dynamics —
        including the non-monotone deep-overload regime where a delayed
        re-arrival lands after a queue drains and *helps*.  The old
        speculative-chain scan was monotone by construction (wire time
        was purely an admission-slack tax); the contract now is the far
        stronger one: met-count equality with the event heap at every
        rung of the ladder (DESIGN.md §7)."""
        reqs, _, _ = pack_requests(HOT.generate(0))
        topo = Topology.full_mesh(3)
        ta = topology_arrays(topo)
        mets = []
        for lam in (0.0, 2.0, 10.0, 50.0, 200.0, 1000.0):
            m = simulate(reqs, ta, SimParams.make(0), policy="least_loaded",
                         capacity=256, depth=128, net=_uniform_net(3, lam))
            lm = LinkModel.uniform(topo, latency=lam, bandwidth=math.inf)
            host = Orchestrator(topo, FastPreferentialQueue,
                                Router(topo, "least_loaded", seed=0),
                                network=lm).run(HOT.generate(0))
            assert int(m.met_deadline) == host.met_deadline, lam
            mets.append(int(m.met_deadline))
        assert min(mets) < mets[0]             # the tax is real
        assert mets != sorted(mets, reverse=True)   # and not a pure tax:
        # deep latency relieves contention on this overloaded fixture,
        # exactly as the heap reports

    def test_netparams_is_a_vmap_axis(self):
        """latency ladder as ONE device call: vmap over stacked NetParams."""
        reqs, _, _ = pack_requests(HOT.generate(0))
        reqs = type(reqs)(*(jnp.asarray(a) for a in reqs))
        ta = topology_arrays(Topology.full_mesh(3))
        ta = type(ta)(*(jnp.asarray(a) for a in ta))
        R = reqs.arrival.shape[0]
        tgt = jnp.full((R, 2), -1, jnp.int32)
        lams = (0.0, 10.0, 200.0)
        stacked = NetParams(
            latency=jnp.stack([_uniform_net(3, l).latency for l in lams]),
            inv_bw=jnp.stack([_uniform_net(3, l).inv_bw for l in lams]))
        run = simulate_fn(policy="least_loaded", capacity=256, depth=128,
                          network=True)
        sweep = jax.vmap(run, in_axes=(None, None, None, None, 0))
        m = sweep(reqs, ta, SimParams.make(0), tgt, stacked)
        assert m.met_deadline.shape == (3,)
        met = np.asarray(m.met_deadline)
        # each cell == the individually-computed point
        for k, lam in enumerate(lams):
            solo = simulate(reqs, ta, SimParams.make(0),
                            policy="least_loaded", capacity=256, depth=128,
                            net=_uniform_net(3, lam))
            assert met[k] == int(solo.met_deadline)

    def test_serialization_cost_scales_with_payload(self):
        """Pure-bandwidth network: 4K referrals pay more wire time than HD
        ones.  The met-rate drop is no longer a sound proxy — the
        event-time scan inherits the heap's real dynamics, where deferred
        re-arrivals can *help* an overloaded fixture — so the wire cost is
        asserted directly on the per-request ``transfer_used`` metric:
        every referral pays exactly ``payload · inv_bw`` per hop."""
        ta = topology_arrays(Topology.full_mesh(3))
        heavy = UniformWorkload([{"S4": 40}] * 3, window=600.0, name="4k")
        light = UniformWorkload([{"S6": 40 * 9}] * 3, window=600.0,
                                name="hd")      # same total work (180 vs 20)
        net = NetParams(latency=np.zeros((3, 3), np.float32),
                        inv_bw=_uniform_net(3, 0.0, inv_bw=4.0).inv_bw)
        per_fwd = {}
        for wl in (heavy, light):
            reqs, _, _ = pack_requests(wl.generate(0))
            priced = simulate(reqs, ta, SimParams.make(0), capacity=512,
                              policy="least_loaded", net=net)
            nfwd = np.asarray(priced.forwards_used)
            wire = np.asarray(priced.transfer_used)
            assert nfwd.sum() > 0
            # each hop pays exactly its frame's serialization time
            payload = np.asarray(reqs.payload)
            np.testing.assert_allclose(wire, nfwd * payload * 4.0,
                                       rtol=1e-5)
            per_fwd[wl.name] = wire.sum() / nfwd.sum()
            # free vs priced outcomes really differ (the wire is not free)
            free = simulate(reqs, ta, SimParams.make(0), capacity=512,
                            policy="least_loaded")
            assert int(free.met_deadline) != int(priced.met_deadline)
        # a 4K frame is 9x an HD frame on the wire, referral for referral
        np.testing.assert_allclose(per_fwd["4k"] / per_fwd["hd"], 9.0,
                                   rtol=1e-4)


# ---------------------------------------------------------------------------
# the fused link_cost kernel vs its oracle (bit-for-bit)
# ---------------------------------------------------------------------------
def _random_fleet(rng, K, N):
    leds, frees = [], []
    for _ in range(K):
        led = jq.empty_ledger(N)
        free = rng.uniform(0, 50)
        for _ in range(rng.randrange(0, N + 2)):
            led, _ = jq.push(led, jnp.float32(rng.choice([5.0, 20.0, 44.0])),
                             jnp.float32(rng.uniform(10, 9000)),
                             jnp.float32(free))
        leds.append(led)
        frees.append(free)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *leds)
    return stacked, jnp.asarray(frees, jnp.float32)


@pytest.mark.parametrize("K,N", [(1, 8), (5, 16), (12, 32)])
def test_link_cost_kernel_matches_ref(K, N):
    rng = random.Random(K * 131 + N)
    stacked, busy = _random_fleet(rng, K, N)
    ps = jnp.asarray([rng.choice([5.0, 20.0, 44.0, 180.0])
                      for _ in range(K)], jnp.float32)
    lat = jnp.asarray([rng.uniform(0.0, 120.0) for _ in range(K)],
                      jnp.float32)
    ibw = jnp.asarray([rng.choice([0.0, 0.1, 1.0]) for _ in range(K)],
                      jnp.float32)
    for d, t, payload in ((400.0, 10.0, 24.8832), (8000.0, 120.0, 2.0736),
                          (60.0, 0.0, 0.9216)):
        got = ops.link_cost(stacked.starts, stacked.ends, stacked.sizes,
                            stacked.n, ps, jnp.float32(d), busy, None,
                            jnp.float32(t), lat, ibw, jnp.float32(payload))
        want = ref.link_cost_ref(stacked.starts, stacked.ends, stacked.sizes,
                                 stacked.n, ps, jnp.float32(d), busy, None,
                                 jnp.float32(t), lat, ibw,
                                 jnp.float32(payload))
        assert np.array_equal(np.asarray(got[0]), np.asarray(want[0])), d
        assert np.array_equal(np.asarray(got[1]), np.asarray(want[1])), d
        np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                                   rtol=1e-6)


def test_link_cost_zero_delay_degenerates_to_fleet_feasibility():
    rng = random.Random(42)
    K, N = 6, 16
    stacked, busy = _random_fleet(rng, K, N)
    ps = jnp.full((K,), 20.0, jnp.float32)
    zeros = jnp.zeros((K,), jnp.float32)
    for d, t in ((300.0, 0.0), (4000.0, 55.0)):
        got, arr, load = ops.link_cost(
            stacked.starts, stacked.ends, stacked.sizes, stacked.n, ps,
            jnp.float32(d), busy, None, jnp.float32(t), zeros, zeros,
            jnp.float32(24.8))
        base_f, base_l = ops.fleet_feasibility(
            stacked.starts, stacked.ends, stacked.sizes, stacked.n, ps,
            jnp.float32(d), jnp.maximum(jnp.float32(t), busy))
        assert np.array_equal(np.asarray(got), np.asarray(base_f))
        np.testing.assert_allclose(np.asarray(load), np.asarray(base_l))
        np.testing.assert_allclose(np.asarray(arr),
                                   np.full((K,), t, np.float32))


def test_link_cost_head_pointer_rows():
    """Retired-slot prefixes give the same verdict as the compacted plain
    ledger, with the arrival delay applied on top."""
    led = jq.empty_ledger(16)
    for (p, d) in ((20.0, 100.0), (44.0, 400.0), (180.0, 9000.0)):
        led, _ = jq.push(led, jnp.float32(p), jnp.float32(d), jnp.float32(0.0))
    h = 2
    starts = jnp.concatenate([jnp.full((h,), -jq.BIG), led.starts[:-h]])
    ends = jnp.concatenate([jnp.full((h,), -jq.BIG), led.ends[:-h]])
    sizes = jnp.concatenate([jnp.zeros((h,)), led.sizes[:-h]])
    for ps, d, lam in ((5.0, 60.0, 0.0), (5.0, 60.0, 30.0),
                       (44.0, 300.0, 10.0), (180.0, 9000.0, 100.0)):
        got, _, _ = ops.link_cost(
            starts[None], ends[None], sizes[None], led.n[None],
            jnp.float32(ps)[None], jnp.float32(d), jnp.zeros((1,)),
            jnp.array([h], jnp.int32), jnp.float32(0.0),
            jnp.float32(lam)[None], jnp.zeros((1,)), jnp.float32(0.0))
        want = jq.feasible(led, jnp.float32(ps), jnp.float32(d),
                           jnp.float32(lam))
        assert bool(got[0]) == bool(want), (ps, d, lam)


# ---------------------------------------------------------------------------
# the fused event_select kernel vs its oracle (bit-for-bit)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("K,N", [(1, 8), (5, 16), (12, 32)])
def test_event_select_kernel_matches_ref(K, N):
    rng = random.Random(K * 17 + N)
    stacked, busy = _random_fleet(rng, K, N)
    speeds = jnp.asarray([rng.choice([0.5, 1.0, 2.0]) for _ in range(K)],
                         jnp.float32)
    lat = jnp.asarray(np.asarray(
        [[0.0 if i == j else rng.uniform(0.0, 120.0) for j in range(K)]
         for i in range(K)], np.float32))
    ibw = jnp.asarray(np.asarray(
        [[0.0 if i == j else rng.choice([0.0, 0.1, 1.0]) for j in range(K)]
         for i in range(K)], np.float32))
    cases = [
        # (t_a, avail_a, t_b, avail_b): both live, tie (fresh must win),
        # buffer earlier, fresh only, buffer only, neither
        (10.0, True, 40.0, True), (25.0, True, 25.0, True),
        (90.0, True, 12.0, True), (10.0, True, 5.0, False),
        (3.0, False, 55.0, True), (1.0, False, 2.0, False),
    ]
    for t_a, av_a, t_b, av_b in cases:
        args = (jnp.float32(t_a), jnp.int32(rng.randrange(K)),
                jnp.float32(rng.uniform(50, 9000)), jnp.float32(20.0),
                jnp.float32(rng.choice([0.92, 24.88])), av_a,
                jnp.float32(t_b), jnp.int32(rng.randrange(K)),
                jnp.float32(rng.uniform(50, 9000)), jnp.float32(44.0),
                jnp.float32(rng.choice([0.92, 24.88])), av_b,
                stacked.starts, stacked.ends, stacked.sizes, stacked.n,
                None, speeds, busy, lat, ibw)
        got = ops.event_select(*args)
        want = ref.event_select_ref(*args)
        assert bool(got[0]) == bool(want[0])              # take_fresh
        if av_a and av_b and t_a == t_b:
            assert bool(got[0])                           # fresh wins ties
        assert float(got[1]) == float(want[1])            # t
        assert int(got[2]) == int(want[2])                # node
        for g, w in zip(got[3:], want[3:]):               # feas/arrive/j/
            assert np.array_equal(np.asarray(g), np.asarray(w))  # cap/load


def test_event_select_zero_net_scores_event_node_at_true_arrival():
    """With zero net tensors the selected node's own column degenerates to
    the plain fleet-feasibility verdict at the event time."""
    rng = random.Random(7)
    K, N = 6, 16
    stacked, busy = _random_fleet(rng, K, N)
    zeros = jnp.zeros((K, K), jnp.float32)
    ones = jnp.ones((K,), jnp.float32)
    for t, d in ((10.0, 300.0), (55.0, 4000.0)):
        sel = ref.event_select_ref(
            jnp.float32(t), jnp.int32(2), jnp.float32(d), jnp.float32(20.0),
            jnp.float32(1.0), True,
            jnp.float32(t + 1), jnp.int32(0), jnp.float32(d),
            jnp.float32(20.0), jnp.float32(1.0), True,
            stacked.starts, stacked.ends, stacked.sizes, stacked.n, None,
            ones, busy, zeros, zeros)
        base_f, _ = ops.fleet_feasibility(
            stacked.starts, stacked.ends, stacked.sizes, stacked.n,
            jnp.full((K,), 20.0, jnp.float32), jnp.float32(d),
            jnp.maximum(jnp.float32(t), busy))
        assert np.array_equal(np.asarray(sel[3]), np.asarray(base_f))
        np.testing.assert_allclose(np.asarray(sel[4]),
                                   np.full((K,), t, np.float32))


# ---------------------------------------------------------------------------
# radio: ingress, uplink budget, handover
# ---------------------------------------------------------------------------
class TestRadio:
    def test_zero_radio_reproduces_base_workload(self):
        topo = Topology.full_mesh(3)
        radio = RadioModel.per_node(topo)       # 0-cost cells, identity-ish
        wl = RadioWorkload(HOT, radio)
        base = HOT.generate(0)
        got = wl.generate(0)
        assert len(got) == len(base)
        for a, b in zip(base, got):
            assert b.arrival_time == a.arrival_time
            assert b.origin_node == a.origin_node
            assert b.service is a.service        # untouched, not copied

    def test_uplink_consumes_sla_budget(self):
        cells = [CellSite(0, node=0, uplink_latency=10.0)]
        radio = RadioModel(cells)
        base = UniformWorkload([{"S6": 5}], window=100.0, name="u")
        got = RadioWorkload(base, radio).generate(0)
        orig = base.generate(0)
        for a, b in zip(orig, got):
            assert b.arrival_time == pytest.approx(a.arrival_time + 10.0)
            assert b.service.deadline == pytest.approx(
                a.service.deadline - 10.0)
            # absolute deadline is anchored at capture time
            assert b.deadline == pytest.approx(a.deadline)

    def test_uplink_bandwidth_prices_the_frame(self):
        topo, lm = paper_campus(1)
        cells = [CellSite(0, node=0, uplink_latency=2.0,
                          uplink_bandwidth=0.625)]
        radio = RadioModel(cells)
        base = UniformWorkload([{"S1": 1}], window=1.0, name="b")
        got = RadioWorkload(base, radio, link=lm).generate(0)[0]
        orig = base.generate(0)[0]
        d_up = 2.0 + 24.8832 / 0.625
        assert got.arrival_time == pytest.approx(orig.arrival_time + d_up)

    def test_handover_rehomes_traffic(self):
        cells = [CellSite(0, node=0), CellSite(1, node=1)]
        radio = RadioModel(cells, attachment={7: 0},
                           mobility={7: [(50.0, 1)]})
        assert radio.ingress(7, 0.0) == 0
        assert radio.ingress(7, 49.9) == 0
        assert radio.ingress(7, 50.0) == 1       # handover applied at t
        assert radio.ingress(7, 1e9) == 1
        assert radio.handovers(7) == 1

    def test_random_mobility_is_deterministic(self):
        topo = Topology.full_mesh(3)
        a = RadioModel.per_node(topo).with_random_mobility(
            20, horizon=1000.0, handovers_per_ue=2.0, seed=3)
        b = RadioModel.per_node(topo).with_random_mobility(
            20, horizon=1000.0, handovers_per_ue=2.0, seed=3)
        assert a.mobility == b.mobility
        assert sum(a.handovers(u) for u in range(20)) > 0

    def test_radio_workload_end_to_end(self):
        """Mobility + uplink pricing through the event-heap core."""
        topo = Topology.full_mesh(3)
        lm = LinkModel.campus(topo)
        radio = RadioModel.from_link(lm).with_random_mobility(
            3, horizon=1200.0, handovers_per_ue=1.0, seed=0)
        wl = RadioWorkload(HOT, radio, link=lm)
        res = Orchestrator(topo, FastPreferentialQueue,
                           Router(topo, seed=0), network=lm).run(
            wl.generate(0))
        assert res.processed == res.total_requests
        # the uplink tax is strictly harmful vs the wired-only run
        base = Orchestrator(topo, FastPreferentialQueue,
                            Router(topo, seed=0), network=lm).run(
            HOT.generate(0))
        assert res.met_deadline <= base.met_deadline

    def test_handover_on_exact_arrival_tick(self):
        """A handover scheduled at exactly a request's capture time takes
        effect for that request (bisect_right: the handover at t owns t),
        and the re-homed workload still cross-validates exactly against
        the event heap."""
        cells = [CellSite(0, node=0), CellSite(1, node=1),
                 CellSite(2, node=2)]
        radio = RadioModel(cells, attachment={0: 0},
                           mobility={0: [(50.0, 1)]})

        svc = Service("s", 1, "x", proc_time=10.0, deadline=400.0)

        class _Ticked(Workload):
            name = "tick"
            n_nodes = 3

            def generate(self, seed):
                return self._finish([
                    Request(service=svc, arrival_time=t, origin_node=0)
                    for t in (49.5, 50.0, 50.5)])

        wl = RadioWorkload(_Ticked(), radio)
        got = wl.generate(0)
        assert [r.origin_node for r in got] == [0, 1, 1]
        # the tick itself is not half-open on the other side: one ULP
        # before the handover still enters the old cell
        assert radio.ingress(0, np.nextafter(50.0, 0.0)) == 0
        rep = run_validation(wl, 0, policy="round_robin",
                             topology=Topology.full_mesh(3))
        assert rep.exact, rep.row()

    def test_uplink_exhausting_sla_is_doa_on_both_engines(self):
        """A request whose uplink alone eats the whole SLA budget must be
        dead on arrival — admitted nowhere, forced, late — identically on
        the event heap and the event-time scan."""
        topo = Topology.full_mesh(2)
        cells = [CellSite(0, node=0, uplink_latency=5000.0),
                 CellSite(1, node=1, uplink_latency=5000.0)]
        radio = RadioModel(cells)
        base = UniformWorkload([{"S6": 4}, {"S6": 4}], window=200.0,
                               name="doa")   # S6 deadline 4000 < uplink 5000
        wl = RadioWorkload(base, radio)
        reqs = wl.generate(0)
        # the budget clamps to the positive floor, never goes negative
        assert all(0 < r.service.deadline <= MIN_DEADLINE for r in reqs)
        res = Orchestrator(topo, FastPreferentialQueue,
                           Router(topo, "round_robin", seed=0)).run(reqs)
        assert res.processed == len(reqs)        # forced pushes still run
        assert res.met_deadline == 0             # ... but never in budget
        rep = run_validation(wl, 0, policy="round_robin", topology=topo)
        assert rep.exact, rep.row()
        assert rep.fleet["met_deadline"] == 0
        assert rep.fleet["processed"] == len(reqs)

    def test_validation_errors(self):
        with pytest.raises(ValueError):
            RadioModel([])
        with pytest.raises(ValueError):
            RadioModel([CellSite(0, 0), CellSite(0, 1)])
        with pytest.raises(ValueError):
            RadioModel([CellSite(0, 0)], mobility={0: [(1.0, 9)]})


# ---------------------------------------------------------------------------
# properties: link latency never helps — in its universally-true forms.
#
# The aggregate form ("met(lam) <= met(0)") holds on the fixture ladders
# above but is NOT universal: brute-force sweeps find rare admission
# cascades (~1/3000 random fleets) where pushing one big request off a
# node frees slack for two small ones, and the host engine shows the
# same under deep overload (EXPERIMENTS.md §Netsim).  What IS universal,
# and what these properties pin, is the per-request economics: a zero
# network is free, and a priced referral chain is always *paid for* —
# no request completes as if it had not traveled.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                # pragma: no cover
    _HYP = False

if _HYP:
    svc_mix = st.lists(
        st.tuples(st.sampled_from([5.0, 20.0, 44.0]),
                  st.sampled_from([60.0, 400.0, 4000.0]),
                  st.integers(0, 2000).map(lambda i: i / 2.0)),
        min_size=5, max_size=40)

    def _pack(mix, n_nodes):
        rng = random.Random(len(mix) * 7 + n_nodes)
        reqs = [Request(service=Service(f"p{p}d{d}", 1, "x", p, d),
                        arrival_time=t, origin_node=rng.randrange(n_nodes))
                for (p, d, t) in mix]
        reqs.sort(key=lambda r: (r.arrival_time, r.rid))
        packed, _, _ = pack_requests(reqs)
        return packed

    @settings(max_examples=25, deadline=None)
    @given(svc_mix, st.integers(2, 4),
           st.sampled_from(["random", "least_loaded", "round_robin",
                            "batched_feasible"]))
    def test_property_zero_latency_is_free(mix, n_nodes, policy):
        """NetParams.zero == no network, per-request, on random fleets."""
        packed = _pack(mix, n_nodes)
        ta = topology_arrays(Topology.full_mesh(n_nodes))
        kw = dict(policy=policy, capacity=128, depth=64)
        a = simulate(packed, ta, SimParams.make(0), **kw)
        b = simulate(packed, ta, SimParams.make(0),
                     net=NetParams.zero(n_nodes), **kw)
        assert np.array_equal(np.asarray(a.outcome), np.asarray(b.outcome))
        assert np.array_equal(np.asarray(a.completion),
                              np.asarray(b.completion))

    @settings(max_examples=25, deadline=None)
    @given(svc_mix, st.integers(2, 4),
           st.sampled_from([2.0, 10.0, 50.0, 250.0, 1500.0]),
           st.sampled_from(["random", "least_loaded", "round_robin"]))
    def test_property_wire_time_is_conserved(mix, n_nodes, lam, policy):
        """Every processed request really paid its referral wire time:
        completion >= arrival + forwards_used * lam + proc.  Latency can
        therefore only ever push THIS request's completion later for the
        same placement — the sound per-request reading of "adding link
        latency never increases met deadlines"."""
        packed = _pack(mix, n_nodes)
        ta = topology_arrays(Topology.full_mesh(n_nodes))
        m = simulate(packed, ta, SimParams.make(0),
                     net=_uniform_net(n_nodes, lam),
                     policy=policy, capacity=128, depth=64)
        completion = np.asarray(m.completion)
        nfwd = np.asarray(m.forwards_used)
        done = completion > 0
        floor = (np.asarray(packed.arrival) + nfwd * lam
                 + np.asarray(packed.proc))
        assert (completion[done] >= floor[done] - 1e-2).all()
        # and met still means met: the admission test saw the wire cost
        d_abs = np.asarray(packed.arrival) + np.asarray(packed.rel_deadline)
        met = np.asarray(m.outcome) == 1
        assert (completion[met] <= d_abs[met] + 1e-2).all()
