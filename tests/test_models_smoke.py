"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step on CPU, asserting output shapes and no NaNs (assignment §f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, shapes_for
from repro.configs.shapes import ShapeSpec
from repro.launch.steps import build_cell, model_module
from repro.models import transformer
from repro.models.common import check_finite, count_params


def small_shape(cfg, kind: str) -> ShapeSpec:
    if cfg.family == "lm":
        return ShapeSpec("smoke", kind, seq_len=16, global_batch=2)
    if cfg.family in ("vit", "resnet"):
        return ShapeSpec("smoke", kind, img_res=cfg.img_res, global_batch=2)
    return ShapeSpec("smoke", kind, img_res=getattr(cfg, "img_res", 64),
                     global_batch=2, steps=2)


def _cell_for(arch, kind):
    cfg = get_smoke_config(arch)
    shape = small_shape(cfg, kind)
    # build_cell reads shapes_for; construct manually for smoke shapes
    from repro.launch import steps as S
    cells = S.shapes_for(cfg)
    cells[shape.name] = shape
    try:
        return S.build_cell(arch, "smoke", cfg=cfg)
    finally:
        cells.pop("smoke", None)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cell = _cell_for(arch, "train")
    args = cell.make_args(jax.random.PRNGKey(0))
    params, opt_state, batch = args
    n = count_params(params)
    assert n > 1000
    step = jax.jit(cell.step_fn)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch} loss is not finite"
    assert bool(check_finite(new_params)), f"{arch} produced non-finite params"
    assert new_opt.step == 1
    # shapes preserved
    jax.tree_util.tree_map(lambda a, b: None if a.shape == b.shape else
                           pytest.fail(f"shape changed {a.shape}->{b.shape}"),
                           params, new_params)
    # gradients flow and a few more steps stay finite
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero gradients"
    p, o = new_params, new_opt
    for _ in range(3):
        p, o, metrics = step(p, o, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: loss diverged"
    assert bool(check_finite(p)), f"{arch}: params diverged"


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step_smoke(arch):
    cfg = get_smoke_config(arch)
    kind = "decode" if cfg.family == "lm" else "serve"
    cell = _cell_for(arch, kind)
    args = cell.make_args(jax.random.PRNGKey(1))
    out = jax.jit(cell.step_fn)(*args)
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves
    assert bool(check_finite(leaves)), f"{arch} serve produced non-finite"


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "gemma3-27b",
                                  "starcoder2-7b", "granite-moe-3b-a800m"])
def test_lm_prefill_decode_consistency(arch):
    """Prefill + decode agree with the plain forward pass on next-token
    logits — the KV-cache path is numerically equivalent."""
    cfg = get_smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    full_logits = transformer.logits_fn(params, tokens, cfg)

    last, cache = transformer.prefill(params, tokens[:, :-1], cfg, max_len=S + 4)
    assert jnp.allclose(last, full_logits[:, -2], atol=2e-2), \
        "prefill last-token logits diverge from forward"

    sliding = cfg.sliding_window is not None and cfg.global_every > 0
    if sliding:
        # rebuild a sliding cache by decoding from scratch
        cache_s = transformer.init_sliding_cache(cfg, B, S + 4)
        logits = None
        for i in range(S):
            logits, cache_s = transformer.decode_step_sliding(
                params, cache_s, tokens[:, i], cfg)
        assert jnp.allclose(logits, full_logits[:, -1], atol=2e-2), \
            "sliding decode diverges from forward"
    else:
        logits, cache = transformer.decode_step(params, cache,
                                                tokens[:, -1], cfg)
        assert jnp.allclose(logits, full_logits[:, -1], atol=2e-2), \
            "decode logits diverge from forward"


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact published numbers."""
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_layers, k.d_model, k.n_heads, k.n_kv_heads) == (61, 7168, 64, 8)
    assert (k.d_ff, k.vocab_size, k.n_experts, k.top_k) == (2048, 163840, 384, 8)
    assert k.total_params() > 9e11, "kimi should be ~1T total params"
    assert k.active_params() < 4e10, "kimi should be ~32B active params"

    g = get_config("granite-moe-3b-a800m")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads) == (32, 1536, 24, 8)
    assert (g.d_ff, g.n_experts, g.top_k, g.vocab_size) == (512, 40, 8, 49155)

    s = get_config("starcoder2-7b")
    assert (s.n_layers, s.d_model, s.n_heads, s.n_kv_heads) == (32, 4608, 36, 4)
    assert (s.d_ff, s.vocab_size) == (18432, 49152)

    m = get_config("gemma3-27b")
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads) == (62, 5376, 32, 16)
    assert (m.d_ff, m.vocab_size) == (21504, 262144)
    assert m.sliding_window == 1024 and m.global_every == 6

    d = get_config("dit-xl2")
    assert (d.img_res, d.patch, d.n_layers, d.d_model, d.n_heads) == \
        (256, 2, 28, 1152, 16)

    u = get_config("unet-sd15")
    assert (u.img_res, u.latent_res, u.ch, u.ch_mult) == (512, 64, 320, (1, 2, 4, 4))

    v = get_config("vit-l16")
    assert (v.n_layers, v.d_model, v.n_heads, v.d_ff, v.patch) == \
        (24, 1024, 16, 4096, 16)
    assert abs(v.total_params() - 304e6) < 30e6

    h = get_config("vit-h14")
    assert (h.n_layers, h.d_model, h.n_heads, h.d_ff, h.patch) == \
        (32, 1280, 16, 5120, 14)

    de = get_config("deit-b")
    assert (de.n_layers, de.d_model, de.n_heads, de.d_ff) == (12, 768, 12, 3072)
    assert de.distill_token

    r = get_config("resnet-50")
    assert r.depths == (3, 4, 6, 3) and r.width == 64
