"""Checkpoint fault-tolerance tests: atomic commit, kill-recovery, keep-k."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,), jnp.bfloat16)},
            "opt": {"m": jnp.ones((8, 16)), "step": jnp.asarray(3, jnp.int32)}}


def trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        t = tree()
        ckpt.save_checkpoint(tmp_path, 100, t)
        restored, manifest = ckpt.restore_latest(tmp_path, t)
        assert manifest["step"] == 100
        assert trees_equal(t, restored)
        # dtypes preserved
        assert restored["params"]["b"].dtype == jnp.bfloat16

    def test_latest_wins(self, tmp_path):
        t1, t2 = tree(1), tree(2)
        ckpt.save_checkpoint(tmp_path, 10, t1)
        ckpt.save_checkpoint(tmp_path, 20, t2)
        restored, manifest = ckpt.restore_latest(tmp_path, t1)
        assert manifest["step"] == 20
        assert trees_equal(t2, restored)

    def test_keep_last_gc(self, tmp_path):
        t = tree()
        for s in (10, 20, 30, 40, 50):
            ckpt.save_checkpoint(tmp_path, s, t, keep_last=2)
        steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
        assert steps == ["step_00000040", "step_00000050"]

    def test_shape_mismatch_rejected(self, tmp_path):
        t = tree()
        ckpt.save_checkpoint(tmp_path, 10, t)
        bad = {"params": {"w": jnp.zeros((4, 4)), "b": t["params"]["b"]},
               "opt": t["opt"]}
        assert ckpt.restore_latest(tmp_path, bad) is None


class TestCrashRecovery:
    def test_halfwritten_checkpoint_ignored(self, tmp_path):
        """Simulated kill mid-save: newest dir lacks the manifest -> resume
        falls back to the previous complete checkpoint."""
        t1, t2 = tree(1), tree(2)
        ckpt.save_checkpoint(tmp_path, 10, t1)
        # fake a crash: a step dir with a shard but no manifest
        broken = Path(tmp_path) / "step_00000020"
        broken.mkdir()
        np.savez(broken / "shard_00000.npz", **{"params/w": np.zeros((8, 16))})
        restored, manifest = ckpt.restore_latest(tmp_path, t1)
        assert manifest["step"] == 10
        assert trees_equal(t1, restored)

    def test_corrupt_manifest_ignored(self, tmp_path):
        t1 = tree(1)
        ckpt.save_checkpoint(tmp_path, 10, t1)
        broken = Path(tmp_path) / "step_00000020"
        broken.mkdir()
        (broken / "manifest.json").write_text("{not json")
        restored, manifest = ckpt.restore_latest(tmp_path, t1)
        assert manifest["step"] == 10

    def test_stale_latest_pointer(self, tmp_path):
        t1 = tree(1)
        ckpt.save_checkpoint(tmp_path, 10, t1)
        (Path(tmp_path) / "LATEST").write_text("step_99999999")  # dangling
        restored, manifest = ckpt.restore_latest(tmp_path, t1)
        assert manifest["step"] == 10

    def test_resume_training_after_kill(self, tmp_path):
        """End-to-end: train 6 steps with ckpt_every=3, 'kill', resume, and
        confirm the run continues from step 3's state deterministically."""
        from repro.configs import get_smoke_config
        from repro.configs.shapes import ShapeSpec
        from repro.launch import steps as S
        from repro.training.train_loop import TrainLoopConfig, run

        cfg = get_smoke_config("deit-b")
        S.shapes_for(cfg)["smoke"] = ShapeSpec("smoke", "train",
                                               img_res=cfg.img_res,
                                               global_batch=2)
        try:
            cell = S.build_cell("deit-b", "smoke", cfg=cfg)
        finally:
            S.shapes_for(cfg).pop("smoke", None)

        full = run(cell, TrainLoopConfig(total_steps=6, ckpt_every=3,
                                         ckpt_dir=str(tmp_path / "a"),
                                         log_every=100, seed=7),
                   log_fn=lambda s: None)

        # "crashed" run: only 3 steps saved
        run(cell, TrainLoopConfig(total_steps=3, ckpt_every=3,
                                  ckpt_dir=str(tmp_path / "b"),
                                  log_every=100, seed=7),
            log_fn=lambda s: None)
        resumed = run(cell, TrainLoopConfig(total_steps=6, ckpt_every=3,
                                            ckpt_dir=str(tmp_path / "b"),
                                            log_every=100, seed=7),
                      log_fn=lambda s: None)
        wa = np.asarray(full["params"]["head"]["w"], np.float32)
        wb = np.asarray(resumed["params"]["head"]["w"], np.float32)
        np.testing.assert_allclose(wa, wb, rtol=1e-5, atol=1e-6)
