"""Integration tests: the MEC-LB simulator reproduces the paper's claims."""
import pytest

from repro.core.request import SERVICES
from repro.core.scenarios import SCENARIOS, generate_requests, total_requests
from repro.core.simulator import SimConfig, run_experiment, run_simulation


class TestScenarioData:
    def test_table1_services(self):
        assert SERVICES["S1"].proc_time == 180 and SERVICES["S1"].deadline == 9000
        assert SERVICES["S4"].proc_time == 180 and SERVICES["S4"].deadline == 4000
        assert SERVICES["S3"].proc_time == 20 and SERVICES["S6"].proc_time == 20
        # proc time proportional to pixels (paper: "proportional to the
        # number of pixels of each resolution")
        r1 = SERVICES["S1"].pixels / SERVICES["S3"].pixels
        r2 = SERVICES["S1"].proc_time / SERVICES["S3"].proc_time
        assert r1 == pytest.approx(r2, rel=0.01)

    def test_table2_totals(self):
        # paper: 6000 / 8000 / 9800 requests
        assert total_requests(1) == 6000
        assert total_requests(2) == 8000
        assert total_requests(3) == 9800
        assert len(SCENARIOS[1]) == 3 and len(SCENARIOS[3]) == 6

    def test_request_generation_deterministic(self):
        a = generate_requests(1, seed=7)
        b = generate_requests(1, seed=7)
        assert [(r.arrival_time, r.service.name, r.origin_node) for r in a] == \
               [(r.arrival_time, r.service.name, r.origin_node) for r in b]
        c = generate_requests(1, seed=8)
        assert [(r.arrival_time) for r in a] != [(r.arrival_time) for r in c]


class TestSimulation:
    def test_all_requests_processed_no_discard(self):
        """Paper uses the non-discarding SFA variant: everything completes."""
        res = run_simulation(SimConfig(scenario=1, queue="fifo", seed=0))
        assert res.processed == res.total_requests == 6000
        assert res.discarded == 0

    def test_discard_variant(self):
        res = run_simulation(SimConfig(scenario=1, queue="fifo", seed=0,
                                       discard_on_exhaust=True))
        assert res.discarded > 0
        assert res.processed + res.discarded == res.total_requests

    def test_forward_cap_respected(self):
        res = run_simulation(SimConfig(scenario=1, queue="fifo", seed=0))
        assert res.forwards <= res.total_requests * 2

    @pytest.mark.parametrize("scenario", [1, 2, 3])
    def test_preferential_beats_fifo(self, scenario):
        """The paper's headline claim (Figs. 5-6): preferential >= FIFO on
        deadline compliance AND on referrals, in every scenario."""
        fifo = run_experiment(scenario, "fifo", n_seeds=5)
        pref = run_experiment(scenario, "preferential", n_seeds=5)
        assert pref.met_rate_mean >= fifo.met_rate_mean - 0.001
        assert pref.forward_rate_mean <= fifo.forward_rate_mean + 0.001

    def test_scenario1_overload_regime(self):
        """Paper: scenario 1 success rate below 20% for both queues."""
        fifo = run_experiment(1, "fifo", n_seeds=5)
        pref = run_experiment(1, "preferential", n_seeds=5)
        assert fifo.met_rate_mean < 0.20
        assert pref.met_rate_mean < 0.20

    def test_scenario3_nearly_equal(self):
        """Paper: with 6 nodes the two queues are nearly identical (+0.01%)."""
        fifo = run_experiment(3, "fifo", n_seeds=5)
        pref = run_experiment(3, "preferential", n_seeds=5)
        assert abs(pref.met_rate_mean - fifo.met_rate_mean) < 0.01

    def test_deterministic_given_seed(self):
        a = run_simulation(SimConfig(scenario=2, queue="preferential", seed=3))
        b = run_simulation(SimConfig(scenario=2, queue="preferential", seed=3))
        assert a.met_deadline == b.met_deadline
        assert a.forwards == b.forwards

    def test_faithful_and_fast_queue_same_results(self):
        a = run_simulation(SimConfig(scenario=1, queue="preferential", seed=1))
        b = run_simulation(SimConfig(scenario=1, queue="preferential_faithful", seed=1))
        assert a.met_deadline == b.met_deadline
        assert a.forwards == b.forwards

    def test_admitted_nonforced_never_miss(self):
        """End-to-end invariant check inside the full simulator."""
        from repro.core.node import MECNode
        admitted = []
        orig = MECNode.try_admit

        def spy(self, request, now, forced):
            ok = orig(self, request, now, forced)
            if ok and not forced:
                admitted.append(request)
            return ok

        MECNode.try_admit = spy
        try:
            run_simulation(SimConfig(scenario=1, queue="preferential", seed=0))
        finally:
            MECNode.try_admit = orig
        assert admitted, "no requests admitted?"
        assert all(r.met_deadline for r in admitted)


class TestForwardPolicies:
    @pytest.mark.parametrize("policy", ["random", "power_of_two",
                                        "least_loaded", "round_robin"])
    def test_policies_run(self, policy):
        res = run_simulation(SimConfig(scenario=1, queue="preferential",
                                       forward_policy=policy, seed=0))
        assert res.processed == res.total_requests

    def test_power_of_two_beats_random(self):
        """Beyond-paper: po2 forwarding reduces missed deadlines vs random
        neighbor choice (classic load-balancing result)."""
        rnd = run_experiment(1, "preferential", n_seeds=5, forward_policy="random")
        po2 = run_experiment(1, "preferential", n_seeds=5, forward_policy="power_of_two")
        assert po2.met_rate_mean >= rnd.met_rate_mean - 0.005
