"""The JAX-native ledger (core/jax_queue.py) is property-tested against the
host preferential queue: identical admission decisions and block layouts
over random request streams."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import jax_queue as jq
from repro.core.block_queue import FastPreferentialQueue
from repro.core.request import Request, Service


def mkreq(p, D, arrival=0.0):
    svc = Service(f"p{p}d{D}", 1, "x", p, D)
    return Request(service=svc, arrival_time=arrival, origin_node=0)


# times quantized to halves so the f32 ledger and the f64 host queue see
# bit-identical boundaries (epsilon-scale deadline ties are undefined
# behavior across precisions, not an algorithmic property)
stream = st.lists(
    st.tuples(st.sampled_from([5.0, 20.0, 44.0, 180.0]),
              st.sampled_from([50.0, 400.0, 4000.0, 9000.0]),
              st.integers(0, 600).map(lambda i: i / 2.0)),
    min_size=1, max_size=40)


@settings(max_examples=150, deadline=None)
@given(stream, st.integers(0, 2**31 - 1))
def test_matches_host_queue(ops, seed):
    host = FastPreferentialQueue()
    led = jq.empty_ledger(64)
    t, cpu_free = 0.0, 0.0
    rngstate = seed
    for (p, D, dt) in ops:
        t += dt
        cpu_free = max(cpu_free, t)
        r = mkreq(p, D, arrival=t)
        ok_host = host.push(r, cpu_free)
        led, ok_jax = jq.push(led, jnp.float32(p), jnp.float32(r.deadline),
                              jnp.float32(cpu_free))
        assert bool(ok_jax) == ok_host, (p, D, t)
        n = int(led.n)
        assert n == len(host)
        if n:
            np.testing.assert_allclose(
                np.asarray(led.starts[:n]),
                [b.start for b in host.blocks], rtol=1e-5, atol=1e-3)
            np.testing.assert_allclose(
                np.asarray(led.ends[:n]),
                [b.end for b in host.blocks], rtol=1e-5, atol=1e-3)
        rngstate = (rngstate * 1103515245 + 12345) % (2**31)
        if rngstate % 3 == 0:
            popped = host.pop()
            led, size = jq.pop(led)
            if popped is not None:
                cpu_free = max(cpu_free, t) + popped.proc_time
                assert float(size) == pytest.approx(popped.proc_time)


def test_feasible_batch_matches_scalar():
    led = jq.empty_ledger(16)
    for (p, d) in ((20.0, 100.0), (44.0, 400.0), (180.0, 9000.0)):
        led, _ = jq.push(led, jnp.float32(p), jnp.float32(d), jnp.float32(0.0))
    ps = jnp.array([5.0, 20.0, 180.0, 500.0], jnp.float32)
    ds = jnp.array([30.0, 60.0, 300.0, 200.0], jnp.float32)
    batch = jq.feasible_batch(led, ps, ds, jnp.float32(0.0))
    singles = [bool(jq.feasible(led, ps[i], ds[i], jnp.float32(0.0)))
               for i in range(4)]
    assert list(np.asarray(batch)) == singles


def test_batched_replica_scoring_under_vmap():
    """The engine's use case: score K requests against R replica ledgers in
    one call — vmap over ledgers of vmap over requests."""
    R, K = 3, 5
    leds = [jq.empty_ledger(8) for _ in range(R)]
    leds[0], _ = jq.push(leds[0], jnp.float32(180.0), jnp.float32(200.0),
                         jnp.float32(0.0))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *leds)
    ps = jnp.full((K,), 44.0, jnp.float32)
    ds = jnp.linspace(50.0, 9000.0, K).astype(jnp.float32)
    score = jax.vmap(lambda led: jq.feasible_batch(led, ps, ds,
                                                   jnp.float32(0.0)))(stacked)
    assert score.shape == (R, K)
    # replica 0 is busy until 200; the tightest request fits only elsewhere
    assert not bool(score[0, 0]) and bool(score[1, 0])


@settings(max_examples=100, deadline=None)
@given(st.lists(st.one_of(
    st.tuples(st.just("push"), st.integers(0, 40).map(lambda i: i / 2.0)),
    st.tuples(st.just("pop"), st.just(0.0))), min_size=1, max_size=30))
def test_event_queue_matches_heapq(ops):
    """The device event buffer replays heapq's (time, seq) order exactly —
    including stable FIFO order among equal timestamps (side='right'
    insertion == monotone sequence numbers)."""
    import heapq
    B = 32
    keys = jnp.full((B,), jq.BIG, jnp.float32)
    vals = (jnp.zeros((B,), jnp.int32),)
    n = jnp.zeros((), jnp.int32)
    heap, seq, popped_host, popped_dev = [], 0, [], []
    for kind, t in ops:
        if kind == "push":
            heapq.heappush(heap, (t, seq))
            keys, vals, n, dropped = jq.event_push(keys, vals, n,
                                                   jnp.float32(t), (seq,),
                                                   True)
            assert not bool(dropped)
            seq += 1
        elif heap:
            popped_host.append(heapq.heappop(heap))
            popped_dev.append((float(keys[0]), int(vals[0][0])))
            keys, vals, n = jq.event_pop(keys, vals, n, True)
    assert popped_dev == [(t, s) for t, s in popped_host]
    assert int(n) == len(heap)
    # and the remaining buffer drains in heap order too
    while heap:
        t, s = heapq.heappop(heap)
        assert (float(keys[0]), int(vals[0][0])) == (t, s)
        keys, vals, n = jq.event_pop(keys, vals, n, True)


def test_event_queue_overflow_and_noop_gating():
    keys = jnp.full((4,), jq.BIG, jnp.float32)
    vals = (jnp.zeros((4,), jnp.int32),)
    n = jnp.zeros((), jnp.int32)
    for i, t in enumerate([3.0, 1.0, 2.0, 1.0]):
        keys, vals, n, dropped = jq.event_push(keys, vals, n,
                                               jnp.float32(t), (i,), True)
        assert not bool(dropped)
    # full: an active push is dropped and REPORTED, an inactive one is not
    keys2, vals2, n2, dropped = jq.event_push(keys, vals, n,
                                              jnp.float32(0.5), (9,), True)
    assert bool(dropped) and int(n2) == 4
    assert np.array_equal(np.asarray(keys2), np.asarray(keys))
    _, _, _, dropped = jq.event_push(keys, vals, n, jnp.float32(0.5), (9,),
                                     False)
    assert not bool(dropped)
    # sorted with the equal-key pair in push order (seq 1 before seq 3)
    assert list(np.asarray(keys)) == [1.0, 1.0, 2.0, 3.0]
    assert list(np.asarray(vals[0])) == [1, 3, 2, 0]
    # inactive pop is a no-op
    k3, v3, n3 = jq.event_pop(keys, vals, n, False)
    assert np.array_equal(np.asarray(k3), np.asarray(keys)) and int(n3) == 4


def test_capacity_limit():
    led = jq.empty_ledger(2)
    for _ in range(2):
        led, ok = jq.push(led, jnp.float32(1.0), jnp.float32(9000.0),
                          jnp.float32(0.0))
        assert bool(ok)
    led, ok = jq.push(led, jnp.float32(1.0), jnp.float32(9000.0),
                      jnp.float32(0.0))
    assert not bool(ok)
