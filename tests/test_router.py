"""Forwarding-policy coverage for the topology-aware Router: determinism
under a fixed rng, exclusion/neighbor invariants, po2 sampling semantics,
stable round-robin cycling, and batched feasibility scoring."""
import random

import pytest

from repro.core.node import MECNode
from repro.core.queues import FIFOQueue
from repro.core.request import Request, Service
from repro.orchestration import ROUTER_POLICIES, Router, Topology


def mkreq(p=20.0, d=9000.0, arrival=0.0):
    svc = Service(f"p{p}", pixels=1, environment="t", proc_time=p, deadline=d)
    return Request(service=svc, arrival_time=arrival, origin_node=0)


def nodes_with_load(loads):
    """One FIFO node per entry, pre-loaded with `load` units of work."""
    nodes = [MECNode(i, FIFOQueue()) for i in range(len(loads))]
    for node, load in zip(nodes, loads):
        if load:
            node.try_admit(mkreq(p=float(load)), 0.0, forced=True)
    return nodes


class TestDeterminism:
    @pytest.mark.parametrize("policy", ["random", "power_of_two",
                                        "least_loaded", "round_robin"])
    def test_same_rng_same_stream(self, policy):
        topo = Topology.full_mesh(5)
        picks = []
        for _ in range(2):
            router = Router(topo, policy, rng=random.Random(123))
            nodes = nodes_with_load([10, 40, 20, 30, 50])
            picks.append([router.choose_id(nodes, src=i % 5)
                          for i in range(40)])
        assert picks[0] == picks[1]


class TestExclusionInvariant:
    @pytest.mark.parametrize("policy", ["random", "power_of_two",
                                        "least_loaded", "round_robin"])
    @pytest.mark.parametrize("topo_factory", [
        lambda: Topology.full_mesh(6),
        lambda: Topology.ring(6),
        lambda: Topology.star(6, hub=2),
    ])
    def test_never_self_always_neighbor(self, policy, topo_factory):
        topo = topo_factory()
        router = Router(topo, policy, seed=7)
        nodes = nodes_with_load([15, 25, 5, 45, 35, 55])
        for step in range(60):
            src = step % topo.n_nodes
            if not topo.neighbors(src):
                continue
            pick = router.choose_id(nodes, src)
            assert pick != src
            assert pick in topo.neighbors(src)


class TestPowerOfTwo:
    def test_picks_less_loaded_of_sample(self):
        """po2 must return whichever of ITS OWN two samples has less
        pending work — replay the sample with an identical rng."""
        topo = Topology.full_mesh(6)
        loads = [10, 60, 20, 50, 30, 40]
        for trial in range(30):
            router = Router(topo, "power_of_two",
                            rng=random.Random(1000 + trial))
            shadow = random.Random(1000 + trial)
            nodes = nodes_with_load(loads)
            cands = topo.neighbors(0)
            a, b = shadow.sample(cands, 2)
            expect = a if loads[a] <= loads[b] else b
            assert router.choose_id(nodes, 0) == expect

    def test_single_candidate_short_circuits(self):
        topo = Topology.star(3, hub=0)
        router = Router(topo, "power_of_two", seed=0)
        nodes = nodes_with_load([0, 0, 0])
        assert router.choose_id(nodes, 1) == 0    # leaf's only neighbor


class TestLeastLoaded:
    def test_minimum_pending_work(self):
        topo = Topology.full_mesh(4)
        router = Router(topo, "least_loaded", seed=0)
        nodes = nodes_with_load([5, 80, 10, 60])
        assert router.choose_id(nodes, 0) == 2    # node 0 excluded
        assert router.choose_id(nodes, 2) == 0


class TestRoundRobinStable:
    def test_cycles_stable_ids_on_full_mesh(self):
        """With a fixed src the rotation visits every other node in id
        order, repeatedly."""
        topo = Topology.full_mesh(4)
        router = Router(topo, "round_robin", seed=0)
        nodes = nodes_with_load([0, 0, 0, 0])
        picks = [router.choose_id(nodes, 0) for _ in range(6)]
        assert picks == [1, 2, 3, 1, 2, 3]

    def test_pointer_meaning_survives_changing_src(self):
        """The regression the legacy policy had: the pointer indexes stable
        node ids, so interleaving different sources must not starve any
        node or double-serve another."""
        topo = Topology.full_mesh(3)
        router = Router(topo, "round_robin", seed=0)
        nodes = nodes_with_load([0, 0, 0])
        picks = [router.choose_id(nodes, src) for src in
                 [0, 1, 2, 0, 1, 2, 0, 1, 2]]
        # pointer walks 0,1,2,0,1,2,... skipping src each time
        assert picks == [1, 2, 0, 1, 2, 0, 1, 2, 0]


class TestBatchedFeasible:
    def test_prefers_feasible_over_lighter_infeasible(self):
        pytest.importorskip("jax")
        from repro.core.block_queue import FastPreferentialQueue
        topo = Topology.full_mesh(3)
        router = Router(topo, "batched_feasible", seed=0)
        # node 1: light FIFO load (95) that still blocks a deadline-100
        # request; node 2: much heavier (500) preferential load, but its
        # block is right-aligned near t=8500 so the front window is free.
        nodes = [MECNode(0, FIFOQueue()), MECNode(1, FIFOQueue()),
                 MECNode(2, FastPreferentialQueue())]
        nodes[1].try_admit(mkreq(p=95.0, d=9000.0), 0.0, forced=True)
        assert nodes[2].try_admit(mkreq(p=500.0, d=9000.0), 0.0, forced=False)
        tight = mkreq(p=10.0, d=100.0)
        # least_loaded would pick node 1 (95 < 500); feasibility flips it
        pick = router.choose_id(nodes, 0, request=tight, now=0.0)
        assert pick == 2

    def test_falls_back_to_least_loaded_when_none_feasible(self):
        jax = pytest.importorskip("jax")
        topo = Topology.full_mesh(3)
        router = Router(topo, "batched_feasible", seed=0)
        nodes = nodes_with_load([0, 500, 200])
        hopeless = mkreq(p=50.0, d=10.0)          # p > d: nobody can serve
        assert router.choose_id(nodes, 0, request=hopeless, now=0.0) == 2

    def test_accounts_for_node_speed(self):
        """On heterogeneous topologies feasibility uses the speed-scaled
        processing time: a fast node can take work a slow one cannot."""
        pytest.importorskip("jax")
        topo = Topology.full_mesh(3, speeds=[1.0, 1.0, 4.0])
        router = Router(topo, "batched_feasible", seed=0)
        nodes = [MECNode(i, FIFOQueue()) for i in range(3)]
        req = mkreq(p=50.0, d=30.0)     # infeasible at 1x, 12.5 UT at 4x
        assert router.choose_id(nodes, 0, request=req, now=0.0) == 2

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            Router(Topology.full_mesh(2), "zigzag")
        assert "batched_feasible" in ROUTER_POLICIES
