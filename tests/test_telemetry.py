"""The telemetry plane's three guarantees (DESIGN.md §8).

1. **Zero-cost when disabled**: ``telemetry=None`` leaves the scan carry
   at 19 arrays (the Optional fields are None pytree leaves that compile
   out) and every output bit-identical to a telemetry-enabled run's
   shared fields — the cube observes, never perturbs.
2. **Fixed-shape, vmappable**: the enabled frame is a static-shape cube;
   under vmap each sweep cell gets its own slice from one device call.
3. **Cross-engine agreement**: the host TraceRecorder's time-binned
   summary matches the device frame bucket-for-bucket — counters and
   occupancy high-water marks exactly (both engines bin with the same
   f32 arithmetic), derived integrals within f32-endpoint tolerance —
   on the paper scenario battery via ``run_validation(telemetry=...)``.

Plus the Chrome-trace export: schema-valid JSON whose span structure
matches the run's admissions and forwards.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleetsim import (SimParams, pack_requests, simulate, simulate_fn,
                            topology_arrays)
from repro.fleetsim.validate import run_validation
from repro.netsim import LinkModel
from repro.orchestration import Topology, UniformWorkload
from repro.telemetry import (KIND_ARRIVAL, KIND_FORWARD, KIND_REARRIVAL,
                             KIND_SERVE, N_KINDS, TelemetryConfig,
                             TelemetrySummary, TraceRecorder, bucket_of_np,
                             bucket_width, compare_summaries,
                             interval_histogram, interval_histogram_np,
                             validate_chrome_trace)

# small but busy: 3 nodes in ~20x overload -> forwards, queueing, late
# completions all present in the cube
HOT = UniformWorkload([{"S1": 30, "S4": 30, "S5": 25, "S6": 25}] * 3,
                      window=1200.0, name="hot")


def _hot_cell():
    reqs, _, _ = pack_requests(HOT.generate(0))
    ta = topology_arrays(Topology.full_mesh(3))
    return reqs, ta, SimParams.make()


# ---------------------------------------------------------------------------
# 1. disabled-path guarantee
# ---------------------------------------------------------------------------
def test_disabled_is_bit_identical():
    reqs, ta, params = _hot_cell()
    kw = dict(policy="least_loaded", capacity=512)
    m0 = simulate(reqs, ta, params, **kw)
    assert m0.telemetry is None
    horizon = float(m0.end_time)
    m1 = simulate(reqs, ta, params, **kw,
                  telemetry=TelemetryConfig(16, horizon))
    assert m1.telemetry is not None
    for fld in ("outcome", "served_by", "completion", "forwards_used",
                "transfer_used", "met_deadline", "processed", "forwards",
                "discarded", "overflow", "window_saturation",
                "event_overflow", "mean_response_time", "end_time"):
        a, b = np.asarray(getattr(m0, fld)), np.asarray(getattr(m1, fld))
        assert np.array_equal(a, b), fld


def test_disabled_adds_no_scan_carries():
    """The compiled-out contract, read off the jaxpr: the telemetry
    fields must not exist as scan carries when disabled (19 state
    arrays) and must add exactly two when enabled (21)."""
    reqs, ta, params = _hot_cell()
    tgt = jnp.full((reqs.arrival.shape[0], 2), -1, jnp.int32)

    def num_carry(fn):
        jaxpr = jax.make_jaxpr(fn)(reqs, ta, params, tgt)
        eqns = list(jaxpr.jaxpr.eqns)
        while eqns:
            eqn = eqns.pop(0)
            if eqn.primitive.name == "scan":
                return eqn.params["num_carry"]
            if "jaxpr" in eqn.params:           # descend through pjit
                eqns = list(eqn.params["jaxpr"].jaxpr.eqns) + eqns
        raise AssertionError("no scan found in jaxpr")

    off = simulate_fn(policy="least_loaded", capacity=512)
    on = simulate_fn(policy="least_loaded", capacity=512,
                     telemetry=TelemetryConfig(16, 12000.0))
    assert num_carry(off) == 19
    assert num_carry(on) == 21


# ---------------------------------------------------------------------------
# 2. the cube: shapes, conservation, vmap
# ---------------------------------------------------------------------------
def test_frame_shapes_and_conservation():
    reqs, ta, params = _hot_cell()
    m = simulate(reqs, ta, params, policy="least_loaded", capacity=512,
                 telemetry=TelemetryConfig(16, 12000.0))
    fr = m.telemetry
    K, NB = 3, 16
    assert fr.counts.shape == (K, NB, N_KINDS)
    assert fr.queue_depth.shape == (K, NB)
    assert fr.busy_time.shape == (K, NB)
    assert fr.occupancy_hwm.shape == (NB,)
    c = np.asarray(fr.counts)
    R = reqs.arrival.shape[0]
    # every request arrives once and terminates once; every forward has
    # exactly one re-arrival (the event plane conserves referrals)
    assert c[..., KIND_ARRIVAL].sum() == R
    assert c[..., KIND_SERVE].sum() == int(m.processed)
    assert c[..., KIND_FORWARD].sum() == int(m.forwards)
    assert c[..., KIND_FORWARD].sum() == c[..., KIND_REARRIVAL].sum()
    # busy time per bucket cannot exceed the bucket
    assert float(np.asarray(fr.busy_time).max()) <= \
        float(np.asarray(fr.bucket_width)) * (1 + 1e-5)


def test_vmapped_sweep_yields_stacked_cube():
    reqs, ta, _ = _hot_cell()
    tgt = jnp.full((reqs.arrival.shape[0], 2), -1, jnp.int32)
    run = simulate_fn(policy="random", capacity=512,
                      telemetry=TelemetryConfig(8, 12000.0))
    sweep = jax.vmap(run, in_axes=(None, None, SimParams(0, None), None))
    m = sweep(reqs, ta, SimParams.make(jnp.arange(3), 1.0), tgt)
    fr = m.telemetry
    assert fr.counts.shape == (3, 3, 8, N_KINDS)
    assert fr.occupancy_hwm.shape == (3, 8)
    # per-cell slices convert; the stacked frame refuses (ambiguous)
    cell = TelemetrySummary.from_frame(
        jax.tree_util.tree_map(lambda a: a[1], fr))
    assert cell.counts.shape == (3, 8, N_KINDS)
    with pytest.raises(ValueError):
        TelemetrySummary.from_frame(fr)


# ---------------------------------------------------------------------------
# 3. cross-engine agreement
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["random", "round_robin"])
def test_host_device_agreement_hot(policy):
    rep = run_validation(HOT, 0, policy=policy, telemetry=12)
    assert rep.telemetry is not None
    assert rep.telemetry.ok, rep.telemetry.row()
    assert rep.exact, rep.row()


def test_host_device_agreement_priced_network():
    topo = Topology.full_mesh(3)
    rep = run_validation(HOT, 0, policy="random", telemetry=12,
                         network=LinkModel.campus(topo), topology=topo)
    assert rep.telemetry is not None and rep.telemetry.ok, \
        rep.telemetry.row()


def test_host_device_agreement_scenario1():
    rep = run_validation("paper/scenario1", 0, policy="random", telemetry=32)
    assert rep.telemetry is not None
    assert rep.telemetry.counts_mismatches == 0
    assert rep.telemetry.occupancy_mismatches == 0
    assert rep.telemetry.ok, rep.telemetry.row()


def test_comparator_flags_disagreement():
    rep = run_validation(HOT, 0, policy="round_robin", telemetry=8)
    host = dev = None
    # rebuild two summaries and poke one bucket
    host = rep.telemetry
    assert host.ok
    # synthetic: a single flipped counter must trip the comparator
    from repro.telemetry import TelemetrySummary as TS
    a = TS(counts=np.zeros((2, 4, N_KINDS), np.int32),
           queue_depth=np.zeros((2, 4), np.float32),
           busy_time=np.zeros((2, 4), np.float32),
           occupancy_hwm=np.zeros((4,), np.int32),
           bucket_width=10.0, horizon=40.0)
    b = TS(counts=a.counts.copy(), queue_depth=a.queue_depth.copy(),
           busy_time=a.busy_time.copy(),
           occupancy_hwm=a.occupancy_hwm.copy(),
           bucket_width=10.0, horizon=40.0)
    b.counts[1, 2, KIND_SERVE] = 1
    agr = compare_summaries(a, b)
    assert not agr.ok and agr.counts_mismatches == 1


# ---------------------------------------------------------------------------
# binning primitives: device == host mirror
# ---------------------------------------------------------------------------
def test_bucket_and_histogram_mirrors_match():
    w = bucket_width(1000.0, 13)
    ts = np.asarray([0.0, 1.0, 76.92, 76.93, 500.0, 999.9, 1000.0, 5000.0],
                    np.float32)
    from repro.telemetry import bucket_of
    dev = np.asarray(bucket_of(jnp.asarray(ts), jnp.float32(w), 13))
    host = np.asarray([bucket_of_np(t, w, 13) for t in ts])
    assert (dev == host).all()
    assert dev[-1] == 12 and dev[-2] == 12      # past-horizon -> last bucket

    lo = np.asarray([0.0, 10.0, 995.0, 100.0], np.float32)
    hi = np.asarray([5.0, 200.0, 1100.0, 90.0], np.float32)  # last inverted
    node = np.asarray([0, 1, 0, 1], np.int32)
    valid = np.asarray([True, True, True, True])
    h_np = interval_histogram_np(lo, hi, node, valid, 2, w, 13)
    h_dev = np.asarray(interval_histogram(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(node),
        jnp.asarray(valid), 2, jnp.float32(w), 13))
    np.testing.assert_allclose(h_np, h_dev, atol=1e-3)
    # integral is truncated at the horizon, inverted intervals contribute 0
    assert abs(h_np.sum() - (5.0 + 190.0 + 5.0)) < 1e-2


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------
def _recorded_host_run(network=None):
    from repro.core.block_queue import FastPreferentialQueue
    from repro.orchestration import Orchestrator, Router
    topo = Topology.full_mesh(3)
    rec = TraceRecorder(network=network)
    requests = HOT.generate(0)
    orch = Orchestrator(topo, FastPreferentialQueue,
                        Router(topo, "least_loaded", seed=0),
                        network=network, hooks=rec.hooks)
    result = orch.run(requests)
    return rec, requests, result, topo


def test_chrome_trace_schema_and_structure(tmp_path):
    rec, requests, result, topo = _recorded_host_run()
    path = tmp_path / "trace.json"
    trace = rec.write(str(path), requests, topo)
    n = validate_chrome_trace(trace)
    assert n == len(trace["traceEvents"]) > 0
    reloaded = json.loads(path.read_text())
    assert validate_chrome_trace(reloaded) == n
    serves = [e for e in trace["traceEvents"]
              if e["ph"] == "X" and e["name"].startswith("serve ")]
    wires = [e for e in trace["traceEvents"]
             if e["ph"] == "X" and e["name"].startswith("fwd ")]
    assert len(serves) == result.processed
    assert len(wires) == result.forwards
    assert all(e["dur"] >= 0 for e in serves + wires)


def test_chrome_trace_validator_rejects_garbage():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": "nope"})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [dict(ph="Z", pid=0, ts=0, name="x")]})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [dict(ph="X", pid=0, ts=-1.0, name="x",
                                  dur=1.0)]})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [dict(ph="X", ts=0.0, name="x", dur=1.0)]})


def test_trace_recorder_summary_matches_device():
    """End-to-end without validate.py plumbing: record a host run, run
    the device with the same horizon, compare."""
    rec, requests, result, topo = _recorded_host_run()
    horizon = float(result.end_time)
    nb = 10
    host = rec.summary(requests, topo, nb, horizon)
    reqs, _, _ = pack_requests(requests)
    # replay the host's forwarding choices so the runs are comparable
    rep = run_validation(HOT, 0, policy="least_loaded", telemetry=nb,
                         topology=topo)
    assert rep.telemetry.ok, rep.telemetry.row()
    assert host.kind_totals()["serve"] == result.processed
    assert host.kind_totals()["forward"] == result.forwards
    # heatmap renders one row per node plus header
    assert len(host.depth_heatmap().splitlines()) == topo.n_nodes + 1
