"""Serving engine tests: deadline-aware admission + batching over a real
JAX model, KV-cache session pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.queues import FIFOQueue
from repro.models import vit
from repro.orchestration import Topology
from repro.serving.engine import (DeadlineAwareEngine, ServeRequest,
                                  ServiceClass, ServingReplica)
from repro.serving.kv_cache import KVCachePool


def const_runner(outputs=None):
    calls = []

    def run_batch(cls_name, payloads):
        calls.append((cls_name, len(payloads)))
        return [f"{cls_name}:{i}" for i in range(len(payloads))]

    run_batch.calls = calls
    return run_batch


def mkcls(name="hd", res=720, deadline=100.0, proc=10.0):
    return ServiceClass(name, res, deadline, proc)


class TestReplica:
    def test_admit_and_serve_in_deadline(self):
        rb = const_runner()
        rep = ServingReplica(0, rb)
        cls = mkcls()
        req = ServeRequest("img", cls, arrival=0.0, rid=0)
        assert rep.try_admit(req, now=0.0, forced=False)
        done, served = rep.step(0.0)
        assert served and served[0].done_at <= served[0].deadline
        assert rep.stats["met"] == 1

    def test_batching_groups_same_class(self):
        rb = const_runner()
        cls = mkcls(proc=10.0)
        cls.batch_proc_time = {1: 10.0, 2: 12.0, 4: 16.0}
        rep = ServingReplica(0, rb, max_batch=4)
        for i in range(4):
            assert rep.try_admit(ServeRequest("x", cls, 0.0, rid=i), 0.0, False)
        done, served = rep.step(0.0)
        assert len(served) == 4
        assert rb.calls == [("hd", 4)]
        assert done == pytest.approx(16.0)     # batched, not 40.0 sequential

    def test_batch_run_stops_at_class_boundary(self):
        # Equal-deadline requests stack leftward in the preferential queue
        # (each right-aligns at the previous block's start — the paper's
        # Alg. 2 semantics), so queue order here is b, a, a.
        rb = const_runner()
        a, b = mkcls("a", deadline=1000.0), mkcls("b", deadline=1000.0)
        rep = ServingReplica(0, rb, max_batch=8)
        rep.try_admit(ServeRequest("x", a, 0.0, rid=0), 0.0, False)
        rep.try_admit(ServeRequest("x", a, 0.0, rid=1), 0.0, False)
        rep.try_admit(ServeRequest("x", b, 0.0, rid=2), 0.0, False)
        _, served = rep.step(0.0)
        assert [r.cls.name for r in served] == ["b"]
        _, served = rep.step(rep.busy_until)
        assert [r.cls.name for r in served] == ["a", "a"]

    def test_rejects_infeasible(self):
        rep = ServingReplica(0, const_runner())
        cls = mkcls(deadline=5.0, proc=10.0)     # can never make it
        assert not rep.try_admit(ServeRequest("x", cls, 0.0, rid=0), 0.0, False)
        assert rep.stats["rejected"] == 1

    def test_works_with_fifo_queue(self):
        rep = ServingReplica(0, const_runner(), queue=FIFOQueue())
        cls = mkcls()
        assert rep.try_admit(ServeRequest("x", cls, 0.0, rid=0), 0.0, False)
        _, served = rep.step(0.0)
        assert len(served) == 1


class TestEngine:
    def test_forwarding_to_free_replica(self):
        rb = const_runner()
        cls = mkcls(deadline=25.0, proc=10.0)
        reps = [ServingReplica(i, rb, max_batch=1) for i in range(2)]
        eng = DeadlineAwareEngine(reps, max_forwards=2)
        # overload replica 0 so the 3rd submit must forward
        for _ in range(3):
            eng.submit("x", cls, now=0.0, origin=0)
        assert eng.forwards >= 1
        eng.drain(0.0)
        stats = eng.stats()
        assert stats["met"] == 3

    def test_forced_after_max_forwards(self):
        rb = const_runner()
        cls = mkcls(deadline=10.0, proc=10.0)
        reps = [ServingReplica(i, rb, max_batch=1) for i in range(2)]
        eng = DeadlineAwareEngine(reps, max_forwards=2)
        for _ in range(6):
            eng.submit("x", cls, now=0.0, origin=0)
        eng.drain(0.0)
        stats = eng.stats()
        assert stats["admitted"] == 6            # nothing is dropped
        assert stats["forced"] >= 1              # some ran late (forced)
        assert stats["met"] + stats["missed"] == 6

    def test_end_to_end_with_real_model(self):
        """The paper's use case with an actual ViT data plane on CPU."""
        cfg = get_smoke_config("deit-b")
        params = vit.init_params(cfg, jax.random.PRNGKey(0))
        fwd = jax.jit(lambda imgs: vit.forward(params, imgs, cfg))

        def run_batch(cls_name, payloads):
            logits = fwd(jnp.stack(payloads))
            return list(np.asarray(jnp.argmax(logits, -1)))

        img = jnp.ones((cfg.img_res, cfg.img_res, 3), jnp.float32)
        cls = ServiceClass("hd", cfg.img_res, deadline=60.0, proc_time=5.0)
        cls.batch_proc_time = {1: 5.0, 2: 6.0, 4: 8.0, 8: 12.0}
        reps = [ServingReplica(i, run_batch, max_batch=8) for i in range(2)]
        eng = DeadlineAwareEngine(reps)
        reqs = [eng.submit(img, cls, now=float(i) * 0.5) for i in range(12)]
        eng.drain(6.0)
        assert all(r.result is not None for r in reqs)
        stats = eng.stats()
        assert stats["met"] + stats["missed"] == 12
        assert stats["met"] >= 10


class TestSpeedScaling:
    """ROADMAP regression: the serving DATA PLANE must honor
    Topology.speed the same way Router._batched_feasible scores it — a
    fast cloud replica is actually faster, in admission and execution."""

    def test_fast_replica_executes_faster(self):
        rb = const_runner()
        cls = mkcls(proc=40.0, deadline=200.0)
        reps = [ServingReplica(i, rb, max_batch=1) for i in range(2)]
        eng = DeadlineAwareEngine(reps, topology=Topology(2, speeds=[1.0, 4.0]))
        slow = eng.submit("x", cls, now=0.0, origin=0)
        fast = eng.submit("x", cls, now=0.0, origin=1)
        eng.drain(0.0)
        assert slow.done_at == pytest.approx(40.0)
        assert fast.done_at == pytest.approx(10.0)      # 40 / 4x

    def test_admission_ledger_uses_scaled_proc(self):
        # deadline 12 < proc 10/0.5=20 on the slow replica: the ledger
        # must reject; the 2x replica (proc 5) must admit
        cls = mkcls(proc=10.0, deadline=12.0)
        slow = ServingReplica(0, const_runner(), speed=0.5)
        fast = ServingReplica(1, const_runner(), speed=2.0)
        assert not slow.try_admit(ServeRequest("x", cls, 0.0, rid=0), 0.0,
                                  False)
        assert fast.try_admit(ServeRequest("x", cls, 0.0, rid=1), 0.0, False)

    def test_batched_step_time_scaled(self):
        rb = const_runner()
        cls = mkcls(proc=10.0, deadline=500.0)
        cls.batch_proc_time = {1: 10.0, 2: 12.0}
        rep = ServingReplica(0, rb, max_batch=2, speed=4.0)
        for i in range(2):
            assert rep.try_admit(ServeRequest("x", cls, 0.0, rid=i), 0.0,
                                 False)
        done, served = rep.step(0.0)
        assert len(served) == 2
        assert done == pytest.approx(12.0 / 4.0)

    def test_engine_applies_topology_speeds(self):
        """two_tier: the engine overwrites replica speeds from the
        topology (source of truth), so the cloud tier really is faster
        and meets deadlines the flat fleet misses."""
        topo = Topology.two_tier(2, n_cloud=1, cloud_speed=4.0)
        reps = [ServingReplica(i, const_runner(), max_batch=1)
                for i in range(3)]
        eng = DeadlineAwareEngine(reps, topology=topo)
        assert [r.speed for r in eng.replicas] == [1.0, 1.0, 4.0]
        cls = mkcls(proc=30.0, deadline=40.0)
        # edge 0 is busy after one admit; the overflow refers to the cloud
        for _ in range(3):
            eng.submit("x", cls, now=0.0, origin=0)
        eng.drain(0.0)
        stats = eng.stats()
        # cloud at 4x serves a 30 UT job in 7.5 UT: everything meets
        assert stats["met"] == 3, stats

    def test_default_topology_keeps_explicit_replica_speed(self):
        """Without an explicit topology the defaulted full mesh must NOT
        clobber a configured ServingReplica(speed=...)."""
        rep = ServingReplica(0, const_runner(), max_batch=1, speed=4.0)
        eng = DeadlineAwareEngine([rep])
        assert rep.speed == 4.0
        r = eng.submit("x", mkcls(proc=40.0, deadline=200.0), now=0.0,
                       origin=0)
        eng.drain(0.0)
        assert r.done_at == pytest.approx(10.0)

    def test_speed_validation(self):
        with pytest.raises(ValueError):
            ServingReplica(0, const_runner(), speed=0.0)


class TestKVCachePool:
    def test_allocate_release(self):
        pool = KVCachePool(n_slots=2, max_len=16)
        a = pool.allocate()
        b = pool.allocate()
        assert pool.allocate() is None          # exhausted
        pool.release(a.session_id)
        c = pool.allocate()
        assert c.slot == a.slot                  # slot recycled

    def test_advance_and_overflow(self):
        pool = KVCachePool(n_slots=1, max_len=4)
        s = pool.allocate()
        for _ in range(4):
            pool.advance(s.session_id)
        with pytest.raises(ValueError):
            pool.advance(s.session_id)

    def test_deadline_eviction(self):
        pool = KVCachePool(n_slots=2, max_len=16)
        a = pool.allocate(deadline=10.0)
        b = pool.allocate(deadline=100.0)
        dead = pool.evict_expired(now=50.0)
        assert dead == [a.session_id]
        assert pool.active == 1
        assert pool.utilization() == 0.5
