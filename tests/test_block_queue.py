"""Unit + property tests for the preferential queue (Algorithms 1-5)."""
import math

import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.block_queue import FastPreferentialQueue, PreferentialQueue
from repro.core.queues import EDFQueue, FIFOQueue
from repro.core.request import Request, Service


def mkreq(p, D, arrival=0.0):
    svc = Service(f"p{p}d{D}", pixels=1, environment="busy", proc_time=p, deadline=D)
    return Request(service=svc, arrival_time=arrival, origin_node=0)


# ---------------------------------------------------------------------------
# Paper scenarios (Figs. 1-3 semantics)
# ---------------------------------------------------------------------------
class TestBasicSemantics:
    def test_empty_queue_right_aligned_at_deadline(self):
        q = PreferentialQueue()
        assert q.push(mkreq(20, 100), cpu_free_time=0.0)
        (b,) = q.blocks
        assert b.start == pytest.approx(80.0)
        assert b.end == pytest.approx(100.0)

    def test_reject_infeasible_deadline(self):
        q = PreferentialQueue()
        assert not q.push(mkreq(50, 30), cpu_free_time=0.0)     # p > D
        assert not q.push(mkreq(10, 5), cpu_free_time=0.0)
        assert len(q) == 0

    def test_tight_request_cuts_in_front(self):
        """Fig. 1: a new tight-deadline request is allocated before an
        already-queued longer-deadline one without disturbing it."""
        q = PreferentialQueue()
        assert q.push(mkreq(180, 9000), 0.0)                     # [8820, 9000]
        assert q.push(mkreq(20, 100), 0.0)                       # fits in front
        starts = [b.start for b in q.blocks]
        assert starts == sorted(starts)
        assert q.blocks[0].request.proc_time == 20
        assert q.blocks[0].end <= 100
        assert q.blocks[1].end <= 9000
        q.check_invariants(0.0)

    def test_gap_accumulation_with_shift(self):
        """Fig. 2c-d: deficit covered by left-shifting earlier blocks."""
        q = PreferentialQueue()
        assert q.push(mkreq(10, 100), 0.0)      # [90, 100]
        assert q.push(mkreq(10, 50), 0.0)       # [40, 50]
        # window between the two blocks is [50, 90]; request needing 60 UT with
        # deadline 90 only fits if the d=50 block shifts left into its slack.
        assert q.push(mkreq(60, 90), 0.0)
        q.check_invariants(0.0)
        assert q.deadlines_respected()
        ends = [b.end for b in q.blocks]
        assert ends == sorted(ends)

    def test_existing_deadlines_never_disturbed(self):
        q = PreferentialQueue()
        reqs = [mkreq(44, 9000), mkreq(20, 4000), mkreq(180, 9000), mkreq(20, 300)]
        admitted = [r for r in reqs if q.push(r, 0.0)]
        q.check_invariants(0.0)
        assert q.deadlines_respected()
        assert len(admitted) == len(q)

    def test_forced_push_appends_late(self):
        """Fig. 3 worst case: forced push processes the request late but does
        not disturb admitted deadlines."""
        q = PreferentialQueue()
        for _ in range(10):
            q.push(mkreq(100, 1000), 0.0)
        assert not q.push(mkreq(500, 400), 0.0)                  # infeasible
        assert q.push(mkreq(500, 400), 0.0, forced=True)         # forced
        q.check_invariants(0.0)
        late = [b for b in q.blocks if b.end > b.request.deadline + 1e-9]
        assert len(late) == 1
        assert late[0].request.proc_time == 500
        assert late[0] is q.blocks[-1]

    def test_forced_compaction_variant(self):
        q = PreferentialQueue(forced_compaction=True)
        q.push(mkreq(10, 1000), 0.0)     # [990, 1000]
        q.push(mkreq(10, 500), 0.0)      # [490, 500]
        assert not q.push(mkreq(2000, 100), 0.0)
        assert q.push(mkreq(2000, 100), 0.0, forced=True)
        # literal reading: everything compacted left before the append
        assert q.blocks[0].start == pytest.approx(0.0)
        assert q.blocks[1].start == pytest.approx(q.blocks[0].end)
        assert q.blocks[2].start == pytest.approx(q.blocks[1].end)

    def test_pop_order_is_time_order(self):
        q = PreferentialQueue()
        q.push(mkreq(180, 9000), 0.0)
        q.push(mkreq(20, 100), 0.0)
        q.push(mkreq(44, 4000), 0.0)
        sizes = []
        while True:
            r = q.pop()
            if r is None:
                break
            sizes.append(r.proc_time)
        assert sizes == [20, 44, 180]

    def test_respects_cpu_free_time(self):
        q = PreferentialQueue()
        # CPU busy until t=90; a request with deadline 100 and p=20 cannot fit.
        assert not q.push(mkreq(20, 100), cpu_free_time=90.0)
        assert q.push(mkreq(10, 100), cpu_free_time=90.0)
        assert q.blocks[0].start >= 90.0


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------
request_strategy = st.tuples(
    st.sampled_from([5.0, 20.0, 44.0, 180.0]),            # proc time
    st.sampled_from([50.0, 400.0, 4000.0, 9000.0]),       # relative deadline
    st.floats(min_value=0.0, max_value=500.0),            # inter-arrival
    st.booleans(),                                        # forced
)


@settings(max_examples=300, deadline=None)
@given(st.lists(request_strategy, min_size=1, max_size=60), st.integers(0, 2**32 - 1))
def test_fast_and_faithful_queues_identical(ops, seed):
    """The O(log n) queue is observationally identical to the O(n) one."""
    q1, q2 = PreferentialQueue(), FastPreferentialQueue()
    t = 0.0
    cpu_free = 0.0
    pop_trigger = seed
    for i, (p, D, dt, forced) in enumerate(ops):
        t += dt
        cpu_free = max(cpu_free, t)
        r1 = mkreq(p, D, arrival=t)
        r2 = Request(service=r1.service, arrival_time=t, origin_node=0)
        ok1 = q1.push(r1, cpu_free, forced)
        ok2 = q2.push(r2, cpu_free, forced)
        assert ok1 == ok2
        lay1 = [(round(b.start, 6), round(b.end, 6)) for b in q1.blocks]
        lay2 = [(round(b.start, 6), round(b.end, 6)) for b in q2.blocks]
        assert lay1 == lay2
        q1.check_invariants()
        q2.check_invariants()
        pop_trigger = (pop_trigger * 1103515245 + 12345) % (2**31)
        if pop_trigger % 3 == 0:
            a = q1.pop()
            q2.pop()
            if a is not None:
                cpu_free = max(cpu_free, t) + a.proc_time


@settings(max_examples=300, deadline=None)
@given(st.lists(request_strategy, min_size=1, max_size=60))
def test_admitted_requests_always_meet_deadlines(ops):
    """System invariant: a non-forced admitted request NEVER misses its
    deadline under the work-conserving executor (DESIGN.md §2 guarantee)."""
    q = FastPreferentialQueue()
    t = 0.0
    busy_until = 0.0
    admitted_normal = []
    pending = []
    events = []
    for (p, D, dt, forced) in ops:
        t += dt
        # drain completions before t (work conserving executor)
        while True:
            free_at = busy_until
            if free_at > t or len(q) == 0:
                break
            r = q.pop()
            comp = max(free_at, 0.0) + r.proc_time
            busy_until = comp
            events.append((r, comp))
        cpu_free = max(t, busy_until)
        r = mkreq(p, D, arrival=t)
        ok = q.push(r, cpu_free, forced)
        if ok and not forced:
            admitted_normal.append(r)
        q.check_invariants()
    # drain the rest
    while len(q) > 0:
        r = q.pop()
        comp = max(busy_until, 0.0) + r.proc_time
        busy_until = comp
        events.append((r, comp))
    comp_by_rid = {r.rid: c for r, c in events}
    for r in admitted_normal:
        assert comp_by_rid[r.rid] <= r.deadline + 1e-6, \
            f"admitted request missed deadline: {r.rid}"


@settings(max_examples=200, deadline=None)
@given(st.lists(request_strategy, min_size=1, max_size=50))
def test_preferential_admits_superset_of_fifo_per_state(ops):
    """Per-push (same incoming request, same cpu_free): whenever FIFO's
    admission test passes on the preferential queue's *work content*, the
    preferential queue also admits (tail position is always an option)."""
    q = FastPreferentialQueue()
    t = 0.0
    for (p, D, dt, forced) in ops:
        t += dt
        r = mkreq(p, D, arrival=t)
        fifo_would = t + q.pending_work() + p <= r.deadline + 1e-9
        ok = q.push(r, t, forced=False)
        if fifo_would:
            assert ok, "preferential rejected a FIFO-admissible request"


@settings(max_examples=200, deadline=None)
@given(st.lists(request_strategy, min_size=1, max_size=40))
def test_blocks_sorted_nonoverlapping(ops):
    q = FastPreferentialQueue()
    t = 0.0
    for (p, D, dt, forced) in ops:
        t += dt
        q.push(mkreq(p, D, arrival=t), t, forced)
        blocks = q.blocks
        for a, b in zip(blocks, blocks[1:]):
            assert a.end <= b.start + 1e-6
        assert q.pending_work() == pytest.approx(sum(b.size for b in blocks))


# ---------------------------------------------------------------------------
# Baseline queues
# ---------------------------------------------------------------------------
class TestFIFO:
    def test_admission(self):
        q = FIFOQueue()
        assert q.push(mkreq(20, 100), 0.0)
        assert q.push(mkreq(20, 100), 0.0)
        # 40 UT queued; completion would be 60 > 55
        assert not q.push(mkreq(20, 55), 0.0)
        assert q.push(mkreq(20, 55), 0.0, forced=True)
        assert len(q) == 3

    def test_order(self):
        q = FIFOQueue()
        q.push(mkreq(20, 9000), 0.0)
        q.push(mkreq(180, 9000), 0.0)
        q.push(mkreq(44, 9000), 0.0)
        assert [q.pop().proc_time for _ in range(3)] == [20, 180, 44]


class TestEDF:
    def test_sorted_by_deadline(self):
        q = EDFQueue()
        q.push(mkreq(10, 9000), 0.0)
        q.push(mkreq(10, 50), 0.0)
        q.push(mkreq(10, 4000), 0.0)
        assert [q.pop().deadline for _ in range(3)] == [50.0, 4000.0, 9000.0]

    def test_admission_protects_existing(self):
        q = EDFQueue()
        assert q.push(mkreq(40, 50), 0.0)
        # would preempt and push the first past its deadline
        assert not q.push(mkreq(40, 45), 0.0)
        assert len(q) == 1

    def test_forced_overflow_runs_after_main(self):
        q = EDFQueue()
        q.push(mkreq(40, 50), 0.0)
        assert q.push(mkreq(100, 10), 0.0, forced=True)
        q.push(mkreq(5, 49), 0.0)
        out = [q.pop().proc_time for _ in range(3)]
        assert out == [5, 40, 100]
