"""repro.telemetry — the unified observability plane of both engines.

One contract, two producers (DESIGN.md §8):

* the **device** event-time fleet scan threads an optional, statically
  gated telemetry carry (``simulate(..., telemetry=TelemetryConfig(nb,
  horizon))``) and returns a :class:`TelemetryFrame` — per-bucket /
  per-node event-kind counters, queue depth, busy time and event-buffer
  occupancy high-water marks, all fixed-shape and vmappable (a cube per
  sweep cell in one device call), and compiled out entirely when
  disabled;
* the **host** event heap records the same dynamics through its Hooks
  via :class:`TraceRecorder`, which additionally exports Chrome-trace-
  event JSON viewable in Perfetto.

Both reduce to :class:`TelemetrySummary`; :func:`compare_summaries`
asserts they agree bucket-for-bucket (counters/occupancy exact, derived
integrals within f32-endpoint tolerance) — enforced on the paper
scenarios by ``python -m repro.fleetsim.validate --telemetry``.

    from repro import telemetry as tel

    rec = tel.TraceRecorder(network=link)
    orch = Orchestrator(topo, FastPreferentialQueue, hooks=rec.hooks, ...)
    result = orch.run(requests)
    rec.write("trace.json", requests)                 # -> ui.perfetto.dev
    host = rec.summary(requests, topo, 32, result.end_time)

    m = fleetsim.simulate(reqs, ta, params,
                          telemetry=tel.TelemetryConfig(32, result.end_time))
    dev = tel.TelemetrySummary.from_frame(m.telemetry)
    assert tel.compare_summaries(host, dev).ok
"""
from repro.telemetry.summary import (DERIVED_ATOL, TelemetryAgreement,
                                     TelemetrySummary, compare_summaries)
from repro.telemetry.timeline import (KIND_ARRIVAL, KIND_DISCARD,
                                      KIND_FORWARD, KIND_NAMES,
                                      KIND_REARRIVAL, KIND_SERVE, N_KINDS,
                                      TelemetryConfig, TelemetryFrame,
                                      bucket_of, bucket_of_np, bucket_width,
                                      interval_histogram,
                                      interval_histogram_np, telemetry_init)
from repro.telemetry.trace import TraceRecorder, validate_chrome_trace

__all__ = [
    "TelemetryConfig", "TelemetryFrame", "TelemetrySummary",
    "TelemetryAgreement", "TraceRecorder",
    "compare_summaries", "validate_chrome_trace",
    "bucket_width", "bucket_of", "bucket_of_np",
    "interval_histogram", "interval_histogram_np", "telemetry_init",
    "KIND_ARRIVAL", "KIND_REARRIVAL", "KIND_FORWARD", "KIND_DISCARD",
    "KIND_SERVE", "KIND_NAMES", "N_KINDS", "DERIVED_ATOL",
]
