"""TelemetrySummary — the engine-neutral time-binned view, plus the
cross-engine comparator.

Both observability paths reduce to the same structure: the device scan's
:class:`~repro.telemetry.timeline.TelemetryFrame` converts via
:meth:`TelemetrySummary.from_frame`, the host
:class:`~repro.telemetry.trace.TraceRecorder` builds one directly from
the hook stream.  :func:`compare_summaries` then extends the repo's
exactness contract from outcomes to dynamics: event-kind counters and
buffer-occupancy high-water marks must agree **exactly** (binning is
bit-identical f32 arithmetic on both engines, DESIGN.md §8), while the
derived time integrals (queue depth, busy time) carry a small tolerance
— their interval endpoints come from f64 host completions vs f32 device
completion chains, so the integrals differ at the last-ulp-of-an-
endpoint level, never structurally.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.telemetry.timeline import KIND_NAMES, N_KINDS, TelemetryFrame

#: default tolerance on the derived integrals, as a fraction of the
#: bucket: |Δbusy| <= DERIVED_ATOL * width, |Δdepth| <= DERIVED_ATOL *
#: peak depth (scale-free).  Worst case per bucket is ~(requests in
#: bucket) x ulp(horizon); measured values sit far below this.
DERIVED_ATOL = 0.02


@dataclasses.dataclass
class TelemetrySummary:
    """Time-binned run dynamics: ``n_buckets`` buckets over ``[0,
    horizon)`` for ``n_nodes`` nodes (see DESIGN.md §8 for the bucket
    contract shared by both engines)."""
    counts: np.ndarray           # (K, NB, N_KINDS) i32
    queue_depth: np.ndarray      # (K, NB) f32 time-average ledger depth
    busy_time: np.ndarray        # (K, NB) f32 CPU-busy UT per bucket
    occupancy_hwm: np.ndarray    # (NB,) i32 in-flight referral high water
    bucket_width: float
    horizon: float

    @property
    def n_nodes(self) -> int:
        return self.counts.shape[0]

    @property
    def n_buckets(self) -> int:
        return self.counts.shape[1]

    @property
    def utilization(self) -> np.ndarray:
        """(K, NB) busy fraction per bucket, in [0, 1]."""
        return self.busy_time / np.float32(self.bucket_width)

    @classmethod
    def from_frame(cls, frame: TelemetryFrame) -> "TelemetrySummary":
        """Device cube -> host summary (one sweep cell: no leading vmap
        axes — index the stacked frame first for sweep outputs)."""
        counts = np.asarray(frame.counts)
        if counts.ndim != 3:
            raise ValueError(
                "from_frame expects one sweep cell (counts of rank 3, got "
                f"shape {counts.shape}); index the vmapped frame first")
        width = float(np.asarray(frame.bucket_width))
        return cls(counts=counts.astype(np.int32),
                   queue_depth=np.asarray(frame.queue_depth, np.float32),
                   busy_time=np.asarray(frame.busy_time, np.float32),
                   occupancy_hwm=np.asarray(frame.occupancy_hwm, np.int32),
                   bucket_width=width,
                   horizon=width * counts.shape[1])

    def kind_totals(self) -> dict:
        """Whole-run event counts per kind (sanity view)."""
        tot = self.counts.sum(axis=(0, 1))
        return {name: int(tot[i]) for i, name in enumerate(KIND_NAMES)}

    def depth_heatmap(self, max_width: int = 72) -> str:
        """ASCII heatmap: one row per node, one cell per bucket, depth
        rendered as 0-9+ (the examples/telemetry_tour.py view)."""
        glyphs = "0123456789"
        nb = min(self.n_buckets, max_width)
        lines = [f"queue depth (time-avg) per bucket, w={self.bucket_width:.0f} UT"]
        for k in range(self.n_nodes):
            row = "".join(
                "+" if d >= 10 else glyphs[int(d)]
                for d in np.clip(self.queue_depth[k, :nb], 0, 10))
            lines.append(f"node {k:3d} |{row}|")
        return "\n".join(lines)


@dataclasses.dataclass
class TelemetryAgreement:
    """Bucket-for-bucket comparison of two summaries (host vs device)."""
    counts_mismatches: int       # (node, bucket, kind) cells that differ
    occupancy_mismatches: int    # buckets whose hwm differs
    depth_max_err: float         # max |Δ time-avg depth| over (node, bucket)
    busy_max_err_frac: float     # max |Δ busy| / bucket width
    depth_tol: float
    busy_tol_frac: float

    @property
    def ok(self) -> bool:
        return (self.counts_mismatches == 0
                and self.occupancy_mismatches == 0
                and self.depth_max_err <= self.depth_tol
                and self.busy_max_err_frac <= self.busy_tol_frac)

    def row(self) -> str:
        tag = "agree" if self.ok else "DISAGREE"
        return (f"counts {self.counts_mismatches} occ "
                f"{self.occupancy_mismatches} mismatches, "
                f"ddepth {self.depth_max_err:.2e} "
                f"dbusy {self.busy_max_err_frac:.2e}w  [{tag}]")


def compare_summaries(host: TelemetrySummary, device: TelemetrySummary,
                      atol: float = DERIVED_ATOL,
                      depth_scale: Optional[float] = None
                      ) -> TelemetryAgreement:
    """The cross-engine telemetry contract, measured.

    Counters and occupancy high-water marks compare exactly; the derived
    integrals compare within ``atol`` of their natural scales (bucket
    width for busy time; ``depth_scale`` — default the peak observed
    depth, floored at 1 — for queue depth).
    """
    for name in ("counts", "queue_depth", "busy_time", "occupancy_hwm"):
        a, b = getattr(host, name), getattr(device, name)
        if a.shape != b.shape:
            raise ValueError(f"summary shapes differ on {name}: "
                             f"{a.shape} vs {b.shape}")
    if not np.isclose(host.bucket_width, device.bucket_width):
        raise ValueError(f"bucket widths differ: {host.bucket_width} vs "
                         f"{device.bucket_width}")
    if depth_scale is None:
        depth_scale = max(1.0, float(host.queue_depth.max(initial=0.0)))
    depth_err = float(np.abs(host.queue_depth
                             - device.queue_depth).max(initial=0.0))
    busy_err = float(np.abs(host.busy_time
                            - device.busy_time).max(initial=0.0))
    return TelemetryAgreement(
        counts_mismatches=int(np.sum(host.counts != device.counts)),
        occupancy_mismatches=int(np.sum(host.occupancy_hwm
                                        != device.occupancy_hwm)),
        depth_max_err=depth_err,
        busy_max_err_frac=busy_err / float(host.bucket_width),
        depth_tol=atol * depth_scale,
        busy_tol_frac=atol)
