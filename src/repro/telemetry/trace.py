"""Host trace export: the event heap's hook stream as a Chrome trace,
plus the same time-binned :class:`~repro.telemetry.summary.
TelemetrySummary` the device scan produces.

:class:`TraceRecorder` plugs into the existing
:class:`~repro.orchestration.orchestrator.Hooks` decision points — no
orchestrator changes — and records every admit / forward / discard /
complete with its node and timestamp.  From that stream it emits:

* **Chrome-trace-event JSON** (:meth:`TraceRecorder.chrome_trace`,
  viewable at https://ui.perfetto.dev): one track per MEC node, with a
  ``queue`` span (admission -> execution start), a ``serve`` span
  (execution -> completion), a ``wire`` span per referral hop (forward
  -> wire-delayed re-arrival) and instant markers for discards.  Times
  are the simulator's abstract UT rendered as microseconds.
* **the telemetry summary** (:meth:`TraceRecorder.summary`) — binned
  identically to the device cube.  Event times are *re-derived* as the
  same f32 chain the device scan computes (``t_0 = f32(arrival)``,
  ``t_{h+1} = f32(t_h + f32(wire delay))`` with the delay evaluated in
  f32 from the NetParams tensors), so bucket indices match the device
  bit-for-bit and the counter / occupancy comparison in
  fleetsim/validate.py ``--telemetry`` is exact, not approximate
  (DESIGN.md §8).

The recorder chains user hooks: pass ``hooks=`` your own and both run.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.orchestration.orchestrator import Hooks
from repro.telemetry.summary import TelemetrySummary
from repro.telemetry.timeline import (KIND_ARRIVAL, KIND_DISCARD,
                                      KIND_FORWARD, KIND_REARRIVAL,
                                      KIND_SERVE, N_KINDS, bucket_width,
                                      interval_histogram_np)


@dataclasses.dataclass
class _Hop:
    src: int
    dst: int
    now: float                   # host (f64) forward time, for the trace
    payload: float               # MB, for the f32 wire-delay mirror


@dataclasses.dataclass
class _Terminal:
    kind: str                    # "serve" | "discard"
    node: int
    now: float
    forced: bool = False


class TraceRecorder:
    """Record the orchestrator's decision stream through its hooks.

    ``network`` (the same :class:`repro.netsim.LinkModel` the
    orchestrator runs under, or None) prices the wire spans and the f32
    re-arrival chain; ``forward_delay`` mirrors the orchestrator's fixed
    per-hop delay.  ``hooks`` chains an existing Hooks object.
    """

    def __init__(self, network=None, forward_delay: float = 0.0,
                 hooks: Optional[Hooks] = None):
        self.network = network
        self.forward_delay = float(forward_delay)
        self._chained = hooks or Hooks()
        self.hops: Dict[int, List[_Hop]] = {}          # rid -> ordered hops
        self.forward_order: List[Tuple[int, int]] = []  # (rid, hop) push order
        self.terminal: Dict[int, _Terminal] = {}
        self.completions: Dict[int, Tuple[int, float]] = {}  # rid -> (node, t)
        if network is not None:
            np_net = network.net_params()
            self._lat32 = np.asarray(np_net.latency, np.float32)
            self._ibw32 = np.asarray(np_net.inv_bw, np.float32)
        else:
            self._lat32 = self._ibw32 = None

    # -- hook plumbing -------------------------------------------------------
    @property
    def hooks(self) -> Hooks:
        """The Hooks object to hand the Orchestrator."""
        return Hooks(on_admit=self._on_admit, on_forward=self._on_forward,
                     on_discard=self._on_discard,
                     on_complete=self._on_complete)

    def _on_admit(self, req, node, now, forced):
        self.terminal[req.rid] = _Terminal("serve", node.node_id, now, forced)
        if self._chained.on_admit:
            self._chained.on_admit(req, node, now, forced)

    def _on_forward(self, req, src, dst, now):
        hops = self.hops.setdefault(req.rid, [])
        self.forward_order.append((req.rid, len(hops)))
        payload = (self.network.payload_of(req.service)
                   if self.network is not None else 0.0)
        hops.append(_Hop(src.node_id, dst.node_id, now, payload))
        if self._chained.on_forward:
            self._chained.on_forward(req, src, dst, now)

    def _on_discard(self, req, node, now):
        self.terminal[req.rid] = _Terminal("discard", node.node_id, now)
        if self._chained.on_discard:
            self._chained.on_discard(req, node, now)

    def _on_complete(self, req, node, now):
        self.completions[req.rid] = (node.node_id, now)
        if self._chained.on_complete:
            self._chained.on_complete(req, node, now)

    # -- the f32 event-time mirror (DESIGN.md §8) ---------------------------
    def _delay32(self, hop: _Hop) -> np.float32:
        """One hop's wire delay, evaluated exactly as the device does:
        ``f32(lat + f32(payload * inv_bw))`` from the f32 NetParams."""
        base = np.float32(self.forward_delay)
        if self._lat32 is None:
            return base
        return np.float32(base + self._lat32[hop.src, hop.dst]
                          + np.float32(hop.payload)
                          * self._ibw32[hop.src, hop.dst])

    def event_chain(self, req) -> Tuple[List[np.float32], np.float32]:
        """Per-hop f32 event times of one request: ``[t_0 .. t_H]`` (the
        arrival and every re-arrival) plus the f32 sum of wire delays —
        the exact values the device scan binned and accumulated."""
        t = np.float32(req.arrival_time)
        times = [t]
        dsum = np.float32(0.0)
        for hop in self.hops.get(req.rid, ()):
            d = self._delay32(hop)
            t = np.float32(t + d)
            dsum = np.float32(dsum + d)
            times.append(t)
        return times, dsum

    # -- Chrome trace export -------------------------------------------------
    def chrome_trace(self, requests: Optional[Sequence] = None,
                     topology=None) -> dict:
        """The run as Chrome-trace-event JSON (Perfetto-viewable).

        One ``pid`` per MEC node; per node a ``strategy`` track with the
        queue/serve spans and instants, and a ``wire`` track with the
        referral spans ending at each hop's re-arrival.  ``requests``
        splits the queue span from the serve span (start = completion −
        proc/speed, with ``topology`` supplying node speeds); without it
        the serve span covers admission to completion.
        """
        ev: List[dict] = []
        nodes = set()
        proc = {}
        if requests is not None:
            for r in requests:
                proc[r.rid] = r.service.proc_time

        def track(pid: int, tid: int, name: str):
            nodes.add(pid)
            ev.append(dict(ph="M", pid=pid, tid=tid, name="thread_name",
                           args=dict(name=name)))

        seen_tracks = set()

        def span(pid, tid, tname, name, ts, dur, args=None):
            if (pid, tid) not in seen_tracks:
                seen_tracks.add((pid, tid))
                track(pid, tid, tname)
            ev.append(dict(ph="X", pid=pid, tid=tid, name=name,
                           ts=float(ts), dur=float(max(dur, 0.0)),
                           cat="mec", args=args or {}))

        def instant(pid, tid, tname, name, ts, args=None):
            if (pid, tid) not in seen_tracks:
                seen_tracks.add((pid, tid))
                track(pid, tid, tname)
            ev.append(dict(ph="i", pid=pid, tid=tid, name=name,
                           ts=float(ts), s="t", cat="mec",
                           args=args or {}))

        for rid, term in self.terminal.items():
            if term.kind == "discard":
                instant(term.node, 0, "strategy", f"discard r{rid}",
                        term.now, dict(rid=rid))
                continue
            node, t_admit = term.node, term.now
            done = self.completions.get(rid)
            if done is None:
                continue
            _, t_done = done
            t_start = t_admit
            if rid in proc:
                spd = topology.speed(node) if topology is not None else 1.0
                t_start = max(t_admit, t_done - proc[rid] / spd)
            if t_start > t_admit:
                span(node, 0, "strategy", f"queue r{rid}", t_admit,
                     t_start - t_admit, dict(rid=rid))
            span(node, 0, "strategy", f"serve r{rid}", t_start,
                 t_done - t_start, dict(rid=rid, forced=term.forced))
        for rid, hops in self.hops.items():
            for h, hop in enumerate(hops):
                dur = float(self._delay32(hop))
                span(hop.src, 1, "wire", f"fwd r{rid}.h{h}", hop.now, dur,
                     dict(rid=rid, dst=hop.dst))
        for pid in sorted(nodes):
            ev.append(dict(ph="M", pid=pid, name="process_name",
                           args=dict(name=f"mec-node-{pid}")))
            ev.append(dict(ph="M", pid=pid, name="process_sort_index",
                           args=dict(sort_index=pid)))
        return dict(traceEvents=ev, displayTimeUnit="ms",
                    otherData=dict(generator="repro.telemetry",
                                   time_unit="UT-as-us"))

    def write(self, path: str, requests: Optional[Sequence] = None,
              topology=None) -> dict:
        """Serialize :meth:`chrome_trace` to ``path``; returns the dict."""
        trace = self.chrome_trace(requests, topology)
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace

    # -- the time-binned summary --------------------------------------------
    def summary(self, requests: Sequence, topology, n_buckets: int,
                horizon: float) -> TelemetrySummary:
        """The host run binned exactly like the device telemetry cube.

        ``requests`` is the workload the orchestrator ran (fresh-arrival
        times and services); ``topology`` supplies node count and speeds
        for the busy/depth intervals.  Counter and occupancy binning
        replays the f32 event chain (see module docstring), so against a
        device run with ``TelemetryConfig(n_buckets, horizon)`` the
        integer halves of the summary agree exactly.
        """
        K = topology.n_nodes
        w = bucket_width(horizon, n_buckets)
        counts = np.zeros((K, n_buckets, N_KINDS), np.int32)
        nb1 = n_buckets - 1

        def bucket(t32) -> int:
            return min(int(np.float32(t32) / w), nb1)

        # fresh arrivals + per-hop chains: counters
        chains: Dict[int, List[np.float32]] = {}
        dsums: Dict[int, np.float32] = {}
        for r in requests:
            times, dsum = self.event_chain(r)
            chains[r.rid] = times
            dsums[r.rid] = dsum
            counts[r.origin_node, bucket(times[0]), KIND_ARRIVAL] += 1
            for h, hop in enumerate(self.hops.get(r.rid, ())):
                counts[hop.src, bucket(times[h]), KIND_FORWARD] += 1
                counts[hop.dst, bucket(times[h + 1]), KIND_REARRIVAL] += 1
            term = self.terminal.get(r.rid)
            if term is not None:
                kind = KIND_SERVE if term.kind == "serve" else KIND_DISCARD
                counts[term.node, bucket(times[-1]), kind] += 1

        # occupancy high water: replay arrival events in the device scan's
        # merge order — fresh (heap-preloaded, lowest seqs) win timestamp
        # ties, re-arrivals order by (time, push order) — sampling the
        # in-flight referral count after each event, exactly where the
        # scan samples ev_n
        events: List[Tuple[np.float32, int, int, int, int]] = []
        fwd_seq = {pair: s for s, pair in enumerate(self.forward_order)}
        for i, r in enumerate(requests):
            events.append((chains[r.rid][0], 0, i, r.rid, 0))
        for (rid, h), s in fwd_seq.items():
            events.append((chains[rid][h + 1], 1, s, rid, h + 1))
        events.sort(key=lambda e: (e[0], e[1], e[2]))
        occ_hwm = np.zeros((n_buckets,), np.int32)
        occ = 0
        for t, cls, _, rid, hop in events:
            if cls == 1:
                occ -= 1                       # the re-arrival pops its event
            if (rid, hop) in fwd_seq:
                occ += 1                       # ... and may push the next one
            b = bucket(t)
            occ_hwm[b] = max(occ_hwm[b], occ)

        # derived integrals from the terminal intervals, f32 like the device
        served, admit_t, start_t, done_t, node = [], [], [], [], []
        for r in requests:
            term = self.terminal.get(r.rid)
            comp = self.completions.get(r.rid)
            if term is None or term.kind != "serve" or comp is None:
                continue
            k, t_done = comp
            ps = np.float32(np.float32(r.service.proc_time)
                            / np.float32(topology.speed(k)))
            a32 = np.float32(np.float32(r.arrival_time) + dsums[r.rid])
            c32 = np.float32(t_done)
            served.append(True)
            admit_t.append(a32)
            start_t.append(np.float32(c32 - ps))
            done_t.append(c32)
            node.append(k)
        valid = np.asarray(served, bool) if served else np.zeros((0,), bool)
        admit_t = np.asarray(admit_t, np.float32)
        start_t = np.asarray(start_t, np.float32)
        done_t = np.asarray(done_t, np.float32)
        node = np.asarray(node, np.int32) if node else np.zeros((0,), np.int32)
        depth = interval_histogram_np(admit_t, start_t, node, valid, K, w,
                                      n_buckets) / w
        busy = interval_histogram_np(start_t, done_t, node, valid, K, w,
                                     n_buckets)
        return TelemetrySummary(counts=counts, queue_depth=depth,
                                busy_time=busy, occupancy_hwm=occ_hwm,
                                bucket_width=float(w),
                                horizon=float(horizon))


# ---------------------------------------------------------------------------
# Chrome-trace schema validation (CI's telemetry smoke job)
# ---------------------------------------------------------------------------
_PHASES_WITH_DUR = {"X"}
_KNOWN_PHASES = {"X", "i", "M", "B", "E"}


def validate_chrome_trace(trace: dict) -> int:
    """Structural check of a Chrome-trace-event JSON object.

    Raises ``ValueError`` on the first violation; returns the number of
    trace events otherwise.  Covers the subset the recorder emits (and
    Perfetto requires): a ``traceEvents`` list whose entries carry a
    known ``ph``, a ``pid``, a numeric non-negative ``ts`` for timed
    phases, and a numeric non-negative ``dur`` for complete events.
    """
    if not isinstance(trace, dict):
        raise ValueError(f"trace must be a dict, got {type(trace).__name__}")
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace['traceEvents'] must be a list")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = e.get("ph")
        if ph not in _KNOWN_PHASES:
            raise ValueError(f"traceEvents[{i}] has unknown ph={ph!r}")
        if "pid" not in e:
            raise ValueError(f"traceEvents[{i}] missing pid")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"traceEvents[{i}] bad ts={ts!r}")
            if "name" not in e:
                raise ValueError(f"traceEvents[{i}] missing name")
        if ph in _PHASES_WITH_DUR:
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"traceEvents[{i}] bad dur={dur!r}")
    return len(events)
