"""Device-side telemetry: the fleet scan's time-binned observability cube.

The event-time fleet simulator (DESIGN.md §7) exposes only end-of-run
aggregates; this module adds the *dynamics* — per-bucket/per-node queue
depth, busy time, event-buffer occupancy and event-kind counters — as
fixed-shape tensors that ride the scan, so ``simulate_fn`` stays jittable
and vmappable (one telemetry cube per sweep cell, all in one device
call) and the whole plane is **statically compiled out when disabled**:
the telemetry fields of the scan carry are ``None`` (empty pytrees), so
a disabled run traces to the exact pre-telemetry jaxpr — bit-identical
outputs, zero added carries, zero cost (guarded in
tests/test_telemetry.py).

Bucket contract (DESIGN.md §8): the run window ``[0, horizon)`` splits
into ``n_buckets`` equal buckets of width ``w = f32(horizon) /
f32(n_buckets)``; a point event at time ``t`` bins into ``min(floor(t /
w), n_buckets - 1)`` — the division, the floor and ``w`` itself are all
computed in float32 with the same operation order on both engines, so
binning is bit-identical and an event exactly on a bucket edge ``k·w``
lands in bucket ``k`` on both.  Time past the last bucket edge counts
into the last bucket for point events and is truncated for the derived
time integrals (depth / busy).

Two halves:

* **carried** (per scan step, two scatters): ``counts[node, bucket,
  kind]`` — the five event kinds below, attributed to the node where the
  strategy ran them — and ``occupancy_hwm[bucket]``, the high-water mark
  of the deferred re-arrival buffer's live count (the device mirror of
  "referrals in flight") sampled after every event step;
* **derived** (one post-scan pass over the terminal per-request arrays,
  nothing carried): ``queue_depth[node, bucket]`` — the time-average
  ledger depth, from each served request's queue residency interval
  ``[arrival + transfer, completion - proc/speed]`` — and
  ``busy_time[node, bucket]`` from its execution interval
  ``[completion - proc/speed, completion]``.

The host :class:`~repro.telemetry.trace.TraceRecorder` computes the same
summary from the event heap's hook stream; fleetsim/validate.py
``--telemetry`` asserts they agree bucket-for-bucket.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

# event kinds, in counts[..., kind] order.  DISCARD folds in fleetsim's
# forced-push overflow (a capacity artifact the validation battery pins
# to zero); SERVE counts admissions, forced ones included — exactly the
# host engine's on_admit / on_discard hook semantics.
KIND_ARRIVAL, KIND_REARRIVAL, KIND_FORWARD, KIND_DISCARD, KIND_SERVE = \
    range(5)
N_KINDS = 5
KIND_NAMES = ("arrival", "rearrival", "forward", "discard", "serve")


class TelemetryConfig(NamedTuple):
    """Static telemetry knobs: both are compile-time constants.

    ``n_buckets`` fixes every telemetry tensor shape; ``horizon`` is the
    end of the binned window (events past it clip into the last bucket).
    For cross-engine comparison pass the host run's ``end_time`` so both
    summaries bin the same window.
    """
    n_buckets: int
    horizon: float

    @property
    def width(self) -> np.float32:
        """Bucket width, computed in f32 — the exact constant both the
        device scan and the host recorder must divide by."""
        return bucket_width(self.horizon, self.n_buckets)


class TelemetryFrame(NamedTuple):
    """The device-resident telemetry cube one simulate call produces.

    Under vmap every array gains the sweep's leading axes — a
    (scenario × policy × seed) sweep yields one stacked cube per cell.
    """
    counts: jnp.ndarray          # (K, NB, N_KINDS) i32 event-kind counters
    queue_depth: jnp.ndarray     # (K, NB) f32 time-average ledger depth
    busy_time: jnp.ndarray       # (K, NB) f32 CPU-busy UT within the bucket
    occupancy_hwm: jnp.ndarray   # (NB,) i32 re-arrival buffer high water
    bucket_width: jnp.ndarray    # () f32 — the f32 width (horizon / NB)

    @property
    def utilization(self) -> jnp.ndarray:
        """(K, NB) busy fraction of each bucket, in [0, 1]."""
        return self.busy_time / self.bucket_width


def bucket_width(horizon: float, n_buckets: int) -> np.float32:
    """The f32 bucket width — ``f32(f32(horizon) / f32(n_buckets))``.

    Computed once on the host in float32 and baked into both engines'
    binning, so ``t / w`` is the same operation on the same operands
    everywhere (DESIGN.md §8: this is what makes bucket agreement exact
    rather than approximate).
    """
    if n_buckets <= 0:
        raise ValueError(f"n_buckets must be positive, got {n_buckets}")
    if not horizon > 0.0:
        raise ValueError(f"horizon must be positive, got {horizon}")
    return np.float32(np.float32(horizon) / np.float32(n_buckets))


def bucket_of(t, width: np.float32, n_buckets: int):
    """Bucket index of event time ``t`` (traced or numpy): ``min(floor(t
    / w), NB - 1)``.  The clip happens on the float side so a +BIG dead-
    step time cannot overflow the int cast."""
    return jnp.clip(t / width, 0, n_buckets - 1).astype(jnp.int32)


def bucket_of_np(t, width: np.float32, n_buckets: int) -> np.ndarray:
    """Host mirror of :func:`bucket_of` — same f32 division, same floor
    (truncation of a non-negative value), same clip."""
    t32 = np.asarray(t, np.float32)
    return np.clip((t32 / width).astype(np.int32), 0, n_buckets - 1)


def interval_histogram(lo: jnp.ndarray, hi: jnp.ndarray, node: jnp.ndarray,
                       valid: jnp.ndarray, n_nodes: int,
                       width, n_buckets: int) -> jnp.ndarray:
    """Per-(node, bucket) total overlap of R intervals ``[lo, hi]``.

    The derived half of the telemetry cube: queue-depth and busy-time
    integrals are both one call over the terminal per-request arrays.
    Time outside ``[0, n_buckets · width)`` is truncated (DESIGN.md §8).
    Invalid rows (never-served requests) contribute nothing; ``node``
    may hold any value on those rows (the scatter drops out-of-range
    indices).  Returns ``(n_nodes, n_buckets)`` sums in UT.
    """
    edges_lo = jnp.arange(n_buckets, dtype=lo.dtype) * width
    edges_hi = edges_lo + width
    ov = jnp.clip(jnp.minimum(hi[:, None], edges_hi[None, :])
                  - jnp.maximum(lo[:, None], edges_lo[None, :]), 0.0)
    ov = jnp.where(valid[:, None], ov, 0.0)
    idx = jnp.where(valid, node, n_nodes)            # n_nodes => dropped
    return jnp.zeros((n_nodes, n_buckets), lo.dtype).at[idx].add(
        ov, mode="drop")


def interval_histogram_np(lo, hi, node, valid, n_nodes: int,
                          width, n_buckets: int) -> np.ndarray:
    """Numpy mirror of :func:`interval_histogram` (f32 throughout), for
    the host-side :class:`~repro.telemetry.summary.TelemetrySummary`."""
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    edges_lo = (np.arange(n_buckets, dtype=np.float32)
                * np.float32(width))
    edges_hi = edges_lo + np.float32(width)
    ov = np.clip(np.minimum(hi[:, None], edges_hi[None, :])
                 - np.maximum(lo[:, None], edges_lo[None, :]), 0.0, None)
    ov[~np.asarray(valid, bool)] = 0.0
    out = np.zeros((n_nodes, n_buckets), np.float32)
    np.add.at(out, np.clip(np.asarray(node), 0, n_nodes - 1), ov)
    return out


def telemetry_init(n_nodes: int, n_buckets: int
                   ) -> "tuple[jnp.ndarray, jnp.ndarray]":
    """Fresh carried-telemetry tensors: (counts, occupancy_hwm)."""
    return (jnp.zeros((n_nodes, n_buckets, N_KINDS), jnp.int32),
            jnp.zeros((n_buckets,), jnp.int32))
