"""Gradient compression (beyond-paper distributed-optimization lever).

int8 quantization with per-tensor scale and error feedback [Seide et al.;
1-bit SGD lineage].  On a pod this shrinks the cross-pod gradient
all-reduce 4x (fp32) / 2x (bf16); the pod axis is the slow link, so the
compressed all-reduce pattern is: quantize → psum int32 → dequantize.
Error feedback keeps the quantization noise from biasing convergence.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda g: quantize_int8(g), grads)


def decompress_tree(cgrads: PyTree, like: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda qs, g: dequantize_int8(qs[0], qs[1], g.dtype),
        cgrads, like, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))


def roundtrip_with_feedback(grads: PyTree, residual: Optional[PyTree]
                            ) -> Tuple[PyTree, PyTree]:
    """Quantize+dequantize with error feedback; returns (grads', residual').

    In a multi-pod run the quantized tensors are what cross the pod axis;
    this helper is the numerics (tested for contraction of the feedback
    loop in tests/test_training.py).
    """
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        total = g.astype(jnp.float32) + r
        q, s = quantize_int8(total)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), total - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
