"""Training driver: data → jitted train_step → checkpoints, restartable.

Fault tolerance model (scaled down to this container, designed for pods):

* checkpoint every ``ckpt_every`` steps (atomic, keep-last-k);
* on (re)start, resume from the newest complete checkpoint — a killed run
  loses at most ``ckpt_every`` steps;
* the data pipeline is deterministic in the step index, so restarts replay
  the exact same batches (no sample skew across failures);
* on a real pod, a failed host triggers re-init with the surviving hosts'
  device count — see training/elastic.py for the remesh path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.launch.steps import Cell, opt_cfg_for
from repro.training import checkpoint as ckpt
from repro.training.data import PrefetchIterator, SyntheticSource
from repro.training.optimizer import init_opt_state

PyTree = Any


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10
    seed: int = 0


def run(cell: Cell, loop_cfg: TrainLoopConfig,
        log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    """Train ``cell`` (a train-kind Cell) for ``total_steps``; resumable."""
    assert cell.shape.kind == "train", "run() needs a train cell"
    key = jax.random.PRNGKey(loop_cfg.seed)
    params, opt_state, _ = cell.make_args(key)

    start_step = 0
    if loop_cfg.ckpt_dir:
        restored = ckpt.restore_latest(loop_cfg.ckpt_dir,
                                       {"params": params, "opt": opt_state})
        if restored is not None:
            tree, manifest = restored
            params, opt_state = tree["params"], tree["opt"]
            start_step = int(manifest["step"])
            log_fn(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(cell.step_fn, donate_argnums=(0, 1))
    batch_specs = cell.arg_specs[2]
    source = SyntheticSource(batch_specs, seed=loop_cfg.seed)
    it = PrefetchIterator(source, start_step=start_step)

    losses = []
    t0 = time.time()
    try:
        for step in range(start_step, loop_cfg.total_steps):
            _, batch = next(it)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % loop_cfg.log_every == 0 or \
                    step == loop_cfg.total_steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                log_fn(f"[train] step {step:5d} loss {loss:.4f} "
                       f"lr {float(metrics['lr']):.2e} "
                       f"gnorm {float(metrics['grad_norm']):.2f}")
            if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
                ckpt.save_checkpoint(loop_cfg.ckpt_dir, step + 1,
                                     {"params": params, "opt": opt_state},
                                     keep_last=loop_cfg.keep_last)
    finally:
        it.close()

    if loop_cfg.ckpt_dir:
        ckpt.save_checkpoint(loop_cfg.ckpt_dir, loop_cfg.total_steps,
                             {"params": params, "opt": opt_state},
                             keep_last=loop_cfg.keep_last)
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "wall_s": time.time() - t0}
