"""Synthetic data pipeline with background prefetch.

Real deployments swap ``SyntheticSource`` for a storage-backed source; the
pipeline contract (per-host sharded batches, double-buffered prefetch,
deterministic per-step seeding for exact restart) is what the trainers use.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

PyTree = Any


class SyntheticSource:
    """Deterministic per-step synthetic batches (restart-reproducible)."""

    def __init__(self, batch_specs: Dict[str, Any], seed: int = 0,
                 label_range: int = 8):
        self.specs = batch_specs
        self.seed = seed
        self.label_range = label_range

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        out = {}
        for name, spec in self.specs.items():
            if not spec.shape:      # scalars (e.g. diffusion 'step')
                out[name] = np.asarray(step, spec.dtype)
            elif np.issubdtype(spec.dtype, np.integer):
                out[name] = rng.integers(
                    0, self.label_range, size=spec.shape).astype(spec.dtype)
            else:
                out[name] = (rng.standard_normal(spec.shape) * 0.1
                             ).astype(spec.dtype)
        return out


class PrefetchIterator:
    """Background-thread prefetch with bounded double-buffering."""

    def __init__(self, source: SyntheticSource, start_step: int = 0,
                 prefetch: int = 2,
                 put_fn: Optional[Callable[[PyTree], PyTree]] = None):
        self.source = source
        self.step = start_step
        self.put_fn = put_fn or (lambda x: x)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, self.put_fn(batch)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
