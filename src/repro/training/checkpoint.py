"""Fault-tolerant checkpointing: sharded npz + manifest, atomic commit.

Layout:
    <dir>/step_000100/
        manifest.json        # step, tree structure, shapes/dtypes, host count
        shard_00000.npz      # this host's param/opt leaves (flattened paths)
    <dir>/LATEST             # atomic pointer file (written last)

Crash-safety: the step directory is written under a temp name and renamed
only after every shard and the manifest are fsynced; LATEST is updated via
write-to-temp + rename.  ``restore_latest`` ignores half-written step dirs,
so a job killed mid-save resumes from the previous complete checkpoint —
exercised by tests/test_checkpoint.py with a simulated kill.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    keep_last: int = 3, host_id: int = 0,
                    extra: Optional[dict] = None) -> Path:
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    shard_path = tmp / f"shard_{host_id:05d}.npz"
    np.savez(shard_path, **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "n_hosts": jax.process_count(),
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    mpath = tmp / "manifest.json"
    mpath.write_text(json.dumps(manifest, indent=1))
    # fsync the directory contents before the atomic rename commit
    for p in (shard_path, mpath):
        fd = os.open(p, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    latest_tmp = base / ".LATEST.tmp"
    latest_tmp.write_text(final.name)
    latest_tmp.rename(base / "LATEST")

    _gc_old(base, keep_last)
    return final


def _gc_old(base: Path, keep_last: int) -> None:
    steps = sorted(p for p in base.glob("step_*") if p.is_dir())
    for p in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(p, ignore_errors=True)
    for p in base.glob(".tmp_step_*"):
        shutil.rmtree(p, ignore_errors=True)


def _valid(step_dir: Path) -> bool:
    m = step_dir / "manifest.json"
    if not m.exists():
        return False
    try:
        manifest = json.loads(m.read_text())
    except json.JSONDecodeError:
        return False
    shard = step_dir / "shard_00000.npz"
    return shard.exists() and "keys" in manifest


def list_checkpoints(ckpt_dir: str) -> List[Path]:
    base = Path(ckpt_dir)
    if not base.exists():
        return []
    return [p for p in sorted(base.glob("step_*")) if _valid(p)]


def restore_checkpoint(step_dir: Path, template: PyTree,
                       host_id: int = 0) -> Tuple[PyTree, dict]:
    """Restore into the structure/dtypes of ``template`` (sharding applied
    by the caller via device_put; resharding to a different mesh is just a
    different device_put — see training/elastic.py)."""
    manifest = json.loads((step_dir / "manifest.json").read_text())
    data = np.load(step_dir / f"shard_{host_id:05d}.npz")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want_dtype = manifest.get("dtypes", {}).get(key)
        if want_dtype and str(arr.dtype) != want_dtype:
            # npz stores extension dtypes (bfloat16, fp8) as raw void bytes;
            # re-view them using the dtype recorded in the manifest.
            arr = arr.view(np.dtype(want_dtype))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: checkpoint "
                             f"{arr.shape} vs template {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def restore_latest(ckpt_dir: str, template: PyTree,
                   host_id: int = 0) -> Optional[Tuple[PyTree, dict]]:
    """Restore the newest complete checkpoint, skipping corrupt ones."""
    base = Path(ckpt_dir)
    pointer = base / "LATEST"
    candidates = list_checkpoints(ckpt_dir)
    if pointer.exists():
        named = base / pointer.read_text().strip()
        if _valid(named):
            candidates = [c for c in candidates if c != named] + [named]
    for step_dir in reversed(candidates):
        try:
            return restore_checkpoint(step_dir, template, host_id)
        except (KeyError, ValueError, OSError, json.JSONDecodeError):
            continue
    return None
