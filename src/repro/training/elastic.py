"""Elastic scaling / failure recovery: remesh parameters across device
counts.

On a real cluster the control plane detects a lost host, restarts the job
with the surviving N' devices, and this module rebuilds the mesh and
re-places the checkpointed state under the new sharding — data parallelism
shrinks, tensor parallelism is preserved when the model axis still fits.
On this container we exercise the same code path across different virtual
device splits (tests/test_training.py::TestElastic).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import AxisType, Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def surviving_mesh(n_devices: int, model_parallel: int) -> Mesh:
    """Largest (data, model) mesh that fits ``n_devices`` devices."""
    mp = model_parallel
    while mp > 1 and (n_devices % mp != 0 or mp > n_devices):
        mp //= 2
    dp = n_devices // mp
    devices = np.array(jax.devices()[:dp * mp]).reshape(dp, mp)
    return Mesh(devices, ("data", "model"),
                axis_types=(AxisType.Auto,) * 2)


def replace_mesh(tree: PyTree, spec_tree: PyTree, mesh: Mesh) -> PyTree:
    """Re-place a host-resident pytree onto a (new) mesh.

    ``spec_tree`` holds PartitionSpecs aligned with ``tree`` (tuples of
    axis names or P objects).  Axes that do not divide are replicated.
    """
    def leaf(x, spec):
        if not isinstance(spec, P):
            spec = P(*spec) if isinstance(spec, tuple) else P()
        fixed = []
        for dim, axes in zip(x.shape, tuple(spec) + (None,) * x.ndim):
            if axes is None:
                fixed.append(None)
                continue
            size = mesh.shape[axes] if isinstance(axes, str) else \
                int(np.prod([mesh.shape[a] for a in axes]))
            fixed.append(axes if dim % size == 0 else None)
        return jax.device_put(x, NamedSharding(mesh, P(*fixed)))

    return jax.tree_util.tree_map(
        leaf, tree, spec_tree,
        is_leaf=lambda s: isinstance(s, (tuple, P)) and not isinstance(s, dict))


def shrink_batch(global_batch: int, old_dp: int, new_dp: int) -> int:
    """Keep per-device batch constant when data parallelism shrinks."""
    per_dev = max(1, global_batch // old_dp)
    return per_dev * new_dp
