"""AdamW + schedules as pure pytree transforms (no optax dependency).

The optimizer state dtype is configurable (``float32`` default; ``bfloat16``
for trillion-parameter configs where fp32 moments cannot fit HBM — see
DESIGN.md §5 / EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32
    schedule: str = "cosine"          # cosine | constant | linear_warmup
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    step: jnp.ndarray                 # scalar int32
    m: PyTree
    v: PyTree


def init_opt_state(params: PyTree, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def opt_state_specs(param_specs: PyTree, cfg: AdamWConfig) -> OptState:
    """ShapeDtypeStruct mirror of ``init_opt_state`` for the dry-run."""
    spec = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.state_dtype)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree_util.tree_map(spec, param_specs),
        v=jax.tree_util.tree_map(spec, param_specs),
    )


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step_f + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((step_f - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    if cfg.schedule == "linear_warmup":
        return cfg.lr * warm * (1.0 - frac)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(params: PyTree, grads: PyTree, state: OptState,
                 cfg: AdamWConfig) -> Tuple[PyTree, OptState, dict]:
    """One AdamW step. Math in fp32; moments stored in ``cfg.state_dtype``."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule_lr(cfg, state.step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step=step, m=new_m, v=new_v), metrics
