"""IBM Granite 3.0 MoE — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155, MoE 40e
top-8.  40 experts do not divide the 16-way model axis, so each expert's
d_ff shards instead (moe_shard="ffn", DESIGN.md §5).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=True, n_experts=40, top_k=8,
    # 40 experts don't divide the 16-way model axis; pad to 48 with masked
    # dummy experts (outputs mathematically identical) so expert parallelism
    # applies — EXPERIMENTS.md §Perf iteration A3.
    moe_shard="expert", n_experts_pad=48, moe_impl="shard_map",
)

SMOKE_CONFIG = LMConfig(
    name="granite-moe-smoke",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=128,
    moe=True, n_experts=5, top_k=2, moe_shard="ffn",
    capacity_factor=64.0,  # drop-free at smoke scale (exact KV-cache consistency)
    remat=False, attn_impl="naive",
)
