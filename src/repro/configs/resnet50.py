"""ResNet-50 [arXiv:1512.03385; paper]: depths 3-4-6-3, width 64, bottleneck."""
from repro.configs.base import ResNetConfig

CONFIG = ResNetConfig(
    name="resnet-50",
    img_res=224, depths=(3, 4, 6, 3), width=64,
)

SMOKE_CONFIG = ResNetConfig(
    name="resnet-smoke",
    img_res=32, depths=(1, 1), width=16, n_classes=10,
)
