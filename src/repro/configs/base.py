"""Architecture / shape configuration dataclasses.

Every assigned architecture gets one module in ``repro/configs`` exporting
``CONFIG`` (the exact published configuration) and ``SMOKE_CONFIG`` (a
reduced same-family configuration for CPU smoke tests).  Shapes are defined
per family in ``repro/configs/shapes.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer LM (dense or MoE)."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                      # per-expert d_ff for MoE
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0      # DeepSeek/Kimi-style shared expert(s)
    # attention flavor
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # window size for local layers
    global_every: int = 0          # every Nth layer is global (gemma3: 6)
    # MLP flavor: swiglu (llama-family) | gelu (starcoder2)
    mlp: str = "swiglu"
    # MoE weight sharding: expert (E over tp) | ffn (per-expert d_ff over tp)
    moe_shard: str = "expert"
    # MoE dispatch: global (einsum/GSPMD baseline) | shard_map (local
    # dispatch + psum combine — the §Perf optimization)
    moe_impl: str = "global"
    # pad the expert dimension to this count (0 = off): makes a non-divisible
    # expert count (granite's 40) expert-shardable over the 16-way model axis
    # (dummy experts are masked out of routing; §Perf iteration A3)
    n_experts_pad: int = 0

    @property
    def n_experts_eff(self) -> int:
        return max(self.n_experts, self.n_experts_pad)
    # ZeRO: additionally shard weights/opt-state over the pod axis (needed by
    # trillion-parameter configs to fit v5e HBM; see DESIGN.md §5)
    zero_over_pods: bool = False
    # numerics
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: bool = True
    attn_impl: str = "chunked"     # naive | chunked | pallas
    attn_chunk: int = 1024
    family: str = "lm"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def mlp_gelu(self) -> bool:
        return self.mlp == "gelu"

    def moe_shard_mode(self) -> str:
        return self.moe_shard

    def active_params(self) -> int:
        """Approximate active parameter count (per-token) for MODEL_FLOPS."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        nmat = 2 if self.mlp == "gelu" else 3
        if self.moe:
            ffn = nmat * d * self.d_ff * (self.top_k + self.n_shared_experts)
            router = d * self.n_experts
        else:
            ffn = nmat * d * self.d_ff
            router = 0
        per_layer = attn + ffn + router + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_size * d

    def total_params(self) -> int:
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        nmat = 2 if self.mlp == "gelu" else 3
        if self.moe:
            ffn = nmat * d * self.d_ff * (self.n_experts + self.n_shared_experts)
            router = d * self.n_experts
        else:
            ffn = nmat * d * self.d_ff
            router = 0
        per_layer = attn + ffn + router + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_size * d


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """Vision transformer (ViT / DeiT) encoder."""
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    distill_token: bool = False     # DeiT
    in_channels: int = 3
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "chunked"
    attn_chunk: int = 512
    family: str = "vit"

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        r = img_res or self.img_res
        return (r // self.patch) ** 2 + 1 + int(self.distill_token)

    def total_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d
        patch_embed = self.in_channels * self.patch ** 2 * d
        return self.n_layers * per_layer + patch_embed + d * self.n_classes


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    img_res: int
    depths: Tuple[int, ...] = (3, 4, 6, 3)
    width: int = 64
    n_classes: int = 1000
    in_channels: int = 3
    param_dtype: str = "bfloat16"
    family: str = "resnet"

    def total_params(self) -> int:
        return 25_600_000   # nominal ResNet-50


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    latent_factor: int = 8          # VAE downsample (f8)
    latent_channels: int = 4
    n_classes: int = 1000
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "chunked"
    attn_chunk: int = 512
    family: str = "dit"

    def latent_res(self, img_res: Optional[int] = None) -> int:
        return (img_res or self.img_res) // self.latent_factor

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        return (self.latent_res(img_res) // self.patch) ** 2

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def total_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 6 * d * d  # attn+mlp+adaLN
        return self.n_layers * per_layer


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    img_res: int
    latent_res: int
    ch: int = 320
    ch_mult: Tuple[int, ...] = (1, 2, 4, 4)
    n_res_blocks: int = 2
    attn_levels: Tuple[int, ...] = (0, 1, 2)   # levels with transformer blocks
    ctx_dim: int = 768                         # text-encoder context (stub)
    ctx_len: int = 77
    n_heads: int = 8
    latent_channels: int = 4
    param_dtype: str = "bfloat16"
    remat: bool = True
    family: str = "unet"

    def total_params(self) -> int:
        return 860_000_000  # nominal SD1.5 UNet


ArchConfig = object  # union marker; families dispatch on .family
