"""Gemma 3 27B — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].

62L d_model=5376 32H (GQA kv=16, head_dim 128) d_ff=21504 vocab=262144.
Local layers use a 1024-token sliding window; every 6th layer is global —
this is the sub-quadratic structure that runs the long_500k decode cell.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="gemma3-27b",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    sliding_window=1024, global_every=6, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = LMConfig(
    name="gemma3-smoke",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
    sliding_window=8, global_every=6,
    remat=False, attn_impl="naive",
)
