"""Stable Diffusion 1.5 UNet [arXiv:2112.10752; paper].

img_res=512 latent=64 ch=320 mult 1-2-4-4, 2 ResBlocks, attention (self +
cross to 77x768 text context) at the three highest-resolution levels.
"""
from repro.configs.base import UNetConfig

CONFIG = UNetConfig(
    name="unet-sd15",
    img_res=512, latent_res=64, ch=320, ch_mult=(1, 2, 4, 4),
    n_res_blocks=2, attn_levels=(0, 1, 2), ctx_dim=768, n_heads=8,
)

SMOKE_CONFIG = UNetConfig(
    name="unet-smoke",
    img_res=64, latent_res=8, ch=32, ch_mult=(1, 2),
    n_res_blocks=1, attn_levels=(1,), ctx_dim=32, ctx_len=7, n_heads=2,
    remat=False,
)
