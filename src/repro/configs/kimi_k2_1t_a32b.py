"""Kimi K2 — trillion-parameter MoE (paper-table) [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384 experts top-8.  ZeRO over the pod axis is required to fit optimizer
state in v5e HBM (DESIGN.md §5); moments kept in bf16 for the same reason.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    moe=True, n_experts=384, top_k=8, moe_shard="expert",
    moe_impl="shard_map",   # local dispatch + psum combine (EXPERIMENTS §Perf A)
    zero_over_pods=True, opt_state_dtype="bfloat16",
)

SMOKE_CONFIG = LMConfig(
    name="kimi-k2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=256,
    moe=True, n_experts=8, top_k=2, moe_shard="expert",
    capacity_factor=64.0,  # drop-free at smoke scale (exact KV-cache consistency)
    remat=False, attn_impl="naive",
)
