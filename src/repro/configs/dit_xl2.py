"""DiT-XL/2 [arXiv:2212.09748; paper].

img_res=256 (f8 latent 32), patch=2, 28L d_model=1152 16H.
"""
from repro.configs.base import DiTConfig

CONFIG = DiTConfig(
    name="dit-xl2",
    img_res=256, patch=2, n_layers=28, d_model=1152, n_heads=16,
)

SMOKE_CONFIG = DiTConfig(
    name="dit-smoke",
    img_res=32, patch=2, n_layers=2, d_model=64, n_heads=4,
    remat=False, attn_impl="naive",
)
