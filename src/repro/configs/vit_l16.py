"""ViT-L/16 [arXiv:2010.11929; paper]: 24L d=1024 16H ff=4096, patch 16."""
from repro.configs.base import ViTConfig

CONFIG = ViTConfig(
    name="vit-l16",
    img_res=224, patch=16, n_layers=24, d_model=1024, n_heads=16, d_ff=4096,
)

SMOKE_CONFIG = ViTConfig(
    name="vit-smoke",
    img_res=32, patch=8, n_layers=2, d_model=64, n_heads=4, d_ff=128,
    n_classes=10, remat=False, attn_impl="naive",
)
