"""Architecture registry: ``--arch <id>`` resolves here.

``get_config(arch)`` / ``get_smoke_config(arch)`` return the exact published
configuration / the reduced same-family smoke configuration.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (DiTConfig, LMConfig, ResNetConfig, UNetConfig,
                                ViTConfig)
from repro.configs.shapes import (FAMILY_SHAPES, ShapeSpec, cell_is_applicable,
                                  shapes_for)

_MODULES: Dict[str, str] = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "starcoder2-7b": "starcoder2_7b",
    "gemma3-27b": "gemma3_27b",
    "dit-xl2": "dit_xl2",
    "unet-sd15": "unet_sd15",
    "vit-l16": "vit_l16",
    "vit-h14": "vit_h14",
    "deit-b": "deit_b",
    "resnet-50": "resnet50",
}

ARCHS: List[str] = list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; options: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE_CONFIG


def all_cells():
    """Every applicable (arch, shape) dry-run cell + skip notes."""
    cells, skips = [], []
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in shapes_for(cfg).items():
            ok, why = cell_is_applicable(cfg, shape)
            if ok:
                cells.append((arch, sname))
            else:
                skips.append((arch, sname, why))
    return cells, skips


__all__ = ["ARCHS", "get_config", "get_smoke_config", "all_cells",
           "shapes_for", "cell_is_applicable", "ShapeSpec", "FAMILY_SHAPES",
           "LMConfig", "ViTConfig", "ResNetConfig", "DiTConfig", "UNetConfig"]
