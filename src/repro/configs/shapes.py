"""Input-shape sets per architecture family (from the assignment).

Each shape names a *step kind*:
* ``train``   — lowers ``train_step`` (forward + backward + optimizer)
* ``prefill`` — lowers ``prefill_step`` (forward, builds KV cache)
* ``decode``  — lowers ``serve_step``  (one new token against a KV cache)
* ``serve``   — lowers ``serve_step``  (pure forward)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                        # train | prefill | decode | serve
    seq_len: int = 0
    global_batch: int = 0
    img_res: int = 0
    steps: int = 0                   # diffusion sampler steps


LM_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", "train", seq_len=4_096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32_768, global_batch=32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", seq_len=32_768, global_batch=128),
    "long_500k":   ShapeSpec("long_500k", "decode", seq_len=524_288, global_batch=1),
}

DIFFUSION_SHAPES: Dict[str, ShapeSpec] = {
    "train_256":  ShapeSpec("train_256", "train", img_res=256, global_batch=256, steps=1000),
    "gen_1024":   ShapeSpec("gen_1024", "serve", img_res=1024, global_batch=4, steps=50),
    "gen_fast":   ShapeSpec("gen_fast", "serve", img_res=512, global_batch=16, steps=4),
    "train_1024": ShapeSpec("train_1024", "train", img_res=1024, global_batch=32, steps=1000),
}

VISION_SHAPES: Dict[str, ShapeSpec] = {
    "cls_224":    ShapeSpec("cls_224", "train", img_res=224, global_batch=256),
    "cls_384":    ShapeSpec("cls_384", "train", img_res=384, global_batch=64),
    "serve_b1":   ShapeSpec("serve_b1", "serve", img_res=224, global_batch=1),
    "serve_b128": ShapeSpec("serve_b128", "serve", img_res=224, global_batch=128),
}

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "vit": VISION_SHAPES,
    "resnet": VISION_SHAPES,
    "dit": DIFFUSION_SHAPES,
    "unet": DIFFUSION_SHAPES,
}


def shapes_for(config) -> Dict[str, ShapeSpec]:
    return FAMILY_SHAPES[config.family]


def cell_is_applicable(config, shape: ShapeSpec) -> Tuple[bool, Optional[str]]:
    """Whether (arch, shape) is a valid dry-run cell.

    ``long_500k`` needs sub-quadratic attention: only architectures with
    sliding-window (local) attention run it (gemma3); pure full-attention
    archs skip it (noted in DESIGN.md §Arch-applicability).
    """
    if config.family == "lm" and shape.name == "long_500k":
        if getattr(config, "sliding_window", None) is None:
            return False, ("pure full-attention architecture; 512k decode "
                           "requires sub-quadratic attention (DESIGN.md)")
    return True, None
