"""DeiT-B [arXiv:2012.12877; paper]: 12L d=768 12H ff=3072 + distill token."""
from repro.configs.base import ViTConfig

CONFIG = ViTConfig(
    name="deit-b",
    img_res=224, patch=16, n_layers=12, d_model=768, n_heads=12, d_ff=3072,
    distill_token=True,
)

SMOKE_CONFIG = ViTConfig(
    name="deit-smoke",
    img_res=32, patch=8, n_layers=2, d_model=48, n_heads=4, d_ff=96,
    n_classes=10, distill_token=True, remat=False, attn_impl="naive",
)
