"""StarCoder2-7B — GQA, RoPE [arXiv:2402.19173; hf].

32L d_model=4608 36H (GQA kv=4, head_dim 128) d_ff=18432 vocab=49152.
GELU MLP (two matrices).  36 q-heads shard unevenly over the 16-way model
axis (GSPMD uneven sharding, verified).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="starcoder2-7b",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152, mlp="gelu",
)

SMOKE_CONFIG = LMConfig(
    name="starcoder2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, mlp="gelu",
    remat=False, attn_impl="naive",
)
