"""Tensor views of the orchestration plane's host objects.

The fleet simulator consumes the same :class:`~repro.orchestration.workload.
Workload` / :class:`~repro.orchestration.topology.Topology` objects as the
event-heap :class:`~repro.orchestration.orchestrator.Orchestrator`, but as
flat arrays the device can scan:

* :class:`RequestArrays` — one row per request, sorted by arrival time
  exactly like the orchestrator's initial event heap.  ``rid`` is the dense
  index 0..R-1 in that order (the host object's global ``rid`` counter is
  process-dependent; the dense index is what per-request outcome arrays key
  on, with ``pack_requests`` returning the dense->host mapping for
  cross-validation).
* :class:`TopologyArrays` — adjacency matrix, padded neighbor lists and
  per-node speeds; everything a traced router policy needs.

Both are NamedTuples of plain arrays, so they stack with ``tree_map`` for
``vmap`` sweeps (e.g. one leading seed axis over per-seed request tensors).

Event tensors (DESIGN.md §7): the event-time scan advances over *events*
— fresh arrivals streamed from the sorted :class:`RequestArrays` through
a cursor, plus deferred re-arrivals in a compact sorted ``(event_buf,)``
buffer of ``(time, rid, node, hops)`` columns.  Every request arrives at
most ``max_forwards + 1`` times, so :func:`event_bound` — the static
``R * (max_forwards + 1)`` worst case — bounds the scan length; callers
may size ``max_events`` tighter (``R + expected forwards + slack``) and
the scan surfaces any shortfall in ``metrics.event_overflow``.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request
from repro.orchestration.topology import Topology


class RequestArrays(NamedTuple):
    """One request per row, arrival-sorted (the scan order)."""
    arrival: np.ndarray        # (R,) f32 arrival time
    proc: np.ndarray           # (R,) f32 unscaled worst-case processing time
    rel_deadline: np.ndarray   # (R,) f32 relative SLA deadline
    origin: np.ndarray         # (R,) i32 origin node id
    service: np.ndarray        # (R,) i32 index into the service name table
    payload: np.ndarray = None  # (R,) f32 frame size in MB (netsim wire
    #                             cost: delay = latency + payload * inv_bw;
    #                             ignored — and may be None — without a
    #                             NetParams)


class TopologyArrays(NamedTuple):
    """Traced view of a Topology: adjacency + padded neighbor lists."""
    adj: np.ndarray            # (K, K) bool, no self loops
    neighbors: np.ndarray      # (K, maxdeg) i32, row i padded with i
    degree: np.ndarray         # (K,) i32
    speeds: np.ndarray         # (K,) f32


def event_bound(n_requests: int, max_forwards: int) -> int:
    """The static worst-case event count of a fleet run: every request is
    processed once per arrival, and a request re-arrives at most
    ``max_forwards`` times — ``R * (max_forwards + 1)`` scan steps cover
    any forwarding realization (the default ``max_events``)."""
    return n_requests * (max_forwards + 1)


def pack_requests(requests: Sequence[Request], dtype=np.float32,
                  payload_fn=None
                  ) -> Tuple[RequestArrays, Tuple[str, ...], List[int]]:
    """Request objects -> (arrays, service name table, host rid per row).

    Rows keep the caller's order, which every Workload already emits sorted
    by ``(arrival_time, rid)`` — the same total order the orchestrator's
    event heap uses for simultaneous arrivals.  ``payload_fn(service)``
    sets the per-request wire payload in MB (default: the netsim frame
    model, ``pixels × bytes_per_pixel``); it only matters when a
    :class:`repro.netsim.NetParams` is passed to ``simulate``.
    """
    if payload_fn is None:
        from repro.netsim.link import default_payload as payload_fn
    names = sorted({r.service.name for r in requests})
    name_id = {s: i for i, s in enumerate(names)}
    arrays = RequestArrays(
        arrival=np.array([r.arrival_time for r in requests], dtype),
        proc=np.array([r.service.proc_time for r in requests], dtype),
        rel_deadline=np.array([r.service.deadline for r in requests], dtype),
        origin=np.array([r.origin_node for r in requests], np.int32),
        service=np.array([name_id[r.service.name] for r in requests],
                         np.int32),
        payload=np.array([payload_fn(r.service) for r in requests], dtype),
    )
    return arrays, tuple(names), [r.rid for r in requests]


def topology_arrays(topology: Topology, dtype=np.float32) -> TopologyArrays:
    """Topology -> TopologyArrays (neighbor rows padded with the own id, so
    out-of-degree gathers stay in range and are masked by ``degree``)."""
    K = topology.n_nodes
    adj = np.zeros((K, K), bool)
    maxdeg = max((topology.degree(i) for i in range(K)), default=0)
    neighbors = np.tile(np.arange(K, dtype=np.int32)[:, None],
                        (1, max(maxdeg, 1)))
    degree = np.zeros((K,), np.int32)
    for i in range(K):
        nbrs = topology.neighbors(i)
        degree[i] = len(nbrs)
        for j, v in enumerate(nbrs):
            adj[i, v] = True
            neighbors[i, j] = v
    return TopologyArrays(adj=adj, neighbors=neighbors, degree=degree,
                          speeds=np.asarray(topology.speeds, dtype))


def scenario_arrays(workload, seed: int, dtype=np.float32
                    ) -> Tuple[RequestArrays, Tuple[str, ...]]:
    """``workload.generate(seed)`` packed for the device (drops the host-rid
    mapping, which only cross-validation needs)."""
    arrays, names, _ = pack_requests(workload.generate(seed), dtype)
    return arrays, names
