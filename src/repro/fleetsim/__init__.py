"""repro.fleetsim — device-resident, vmappable fleet simulator.

The event-heap :class:`~repro.orchestration.orchestrator.Orchestrator`
is the semantic reference; this package is the same strategy as stacked
``(num_nodes, capacity)`` ledger tensors scanned end-to-end in JAX, built
for million-request sweeps: one :func:`simulate` call jits whole, and
``vmap`` over :class:`SimParams` / stacked request tensors turns a full
(scenario x policy x seed) table into a single device call.

    from repro.fleetsim import SimParams, simulate, scenario_arrays
    from repro.fleetsim import topology_arrays
    from repro.orchestration import Topology, get_workload

    reqs, names = scenario_arrays(get_workload("paper/scenario1"), seed=0)
    topo = topology_arrays(Topology.full_mesh(3))
    m = simulate(reqs, topo, SimParams.make(seed=0), policy="least_loaded")
    print(float(m.met_rate), int(m.forwards))

Equivalence with the event heap is cross-validated in
:mod:`repro.fleetsim.validate` (exact for deterministic policies, exact
under forwarding-trace replay otherwise — DESIGN.md §5).
"""
from repro.fleetsim.arrays import (RequestArrays, TopologyArrays,
                                   event_bound, pack_requests,
                                   scenario_arrays, topology_arrays)
from repro.fleetsim.core import (DISCARDED, LATE, MET, OVERFLOW, PENDING,
                                 POLICIES, FleetMetrics, SimParams, simulate,
                                 simulate_fn)
from repro.netsim.link import NetParams          # the vmappable network axis

__all__ = [
    "RequestArrays", "TopologyArrays", "event_bound", "pack_requests",
    "scenario_arrays", "topology_arrays",
    "FleetMetrics", "NetParams", "SimParams", "simulate", "simulate_fn",
    "POLICIES", "PENDING", "MET", "LATE", "DISCARDED", "OVERFLOW",
]
