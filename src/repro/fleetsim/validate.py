"""Cross-validation: fleetsim vs the event-heap Orchestrator.

The contract (DESIGN.md §5, §7): on identical workloads — under **any**
link pricing, zero or priced — the event-time fleet simulator reproduces
the event heap's per-request ``(outcome, serving node, transfer time)``

* **exactly** for deterministic forwarding policies (``round_robin``,
  ``batched_feasible``), and
* **exactly under trace replay** for the stochastic ones — the host run
  records every forwarding choice through ``Hooks.on_forward`` and
  fleetsim replays it (``policy="trace"``), so any dynamics divergence
  (admission, timing, tie-breaking, event ordering) still surfaces as an
  outcome mismatch while the Mersenne-vs-threefry rng stream difference
  is factored out,

modulo float32-boundary flips: the host queue schedules in float64, the
device ledger in float32, so a request whose feasibility / deadline margin
is below f32 resolution (~1e-2 at the paper's 1e5-UT timescale) can land
on the other side of the test — and under a priced network two re-arrival
events closer together than f32 resolution can swap order.  Empirically
neither has produced a mismatch (see EXPERIMENTS.md §Netsim);
``run_validation`` reports exact counts and the per-request mismatch list
so the tolerance is measured, not assumed.

    PYTHONPATH=src python -m repro.fleetsim.validate            # 3 scenarios
    PYTHONPATH=src python -m repro.fleetsim.validate --policy round_robin
    PYTHONPATH=src python -m repro.fleetsim.validate --net campus
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.block_queue import FastPreferentialQueue
from repro.fleetsim import core as fcore
from repro.fleetsim.arrays import pack_requests, topology_arrays
from repro.netsim import LinkModel
from repro.orchestration import (Hooks, Orchestrator, Router, Topology,
                                 Workload, get_workload)
from repro.telemetry import (TelemetryAgreement, TelemetryConfig,
                             TelemetrySummary, TraceRecorder,
                             compare_summaries)

# host policies fleetsim replays move-for-move without a trace
DETERMINISTIC = ("round_robin", "batched_feasible")

#: |host - fleet| tolerance on per-request wire time (f32 sums vs f64)
TRANSFER_ATOL = 1e-2


@dataclasses.dataclass
class ValidationReport:
    scenario: str
    seed: int
    policy: str
    total: int
    host: Dict[str, float]
    fleet: Dict[str, float]
    outcome_mismatches: int          # per-request outcome-code disagreements
    node_mismatches: int             # per-request serving-node disagreements
    transfer_max_err: float          # max |per-request wire time| difference
    met_diff_pp: float               # |met-rate difference| in percent points
    capacity: int
    telemetry: Optional[TelemetryAgreement] = None   # --telemetry only

    @property
    def exact(self) -> bool:
        return (self.outcome_mismatches == 0 and self.node_mismatches == 0
                and self.transfer_max_err <= TRANSFER_ATOL
                and (self.telemetry is None or self.telemetry.ok))

    def row(self) -> str:
        tag = "exact" if self.exact else \
            f"{self.outcome_mismatches}o/{self.node_mismatches}n mismatches"
        tel = "" if self.telemetry is None else f"  tel: {self.telemetry.row()}"
        return (f"{self.scenario:18s} seed={self.seed} {self.policy:16s} "
                f"met {self.host['met_deadline']:6.0f}/{self.fleet['met_deadline']:6.0f} "
                f"fwd {self.host['forwards']:6.0f}/{self.fleet['forwards']:6.0f} "
                f"disc {self.host['discarded']:5.0f}/{self.fleet['discarded']:5.0f} "
                f"dmet {self.met_diff_pp:5.3f}pp "
                f"dwire {self.transfer_max_err:7.1e}  [{tag}]{tel}")


def _host_run(workload: Workload, topology: Topology, seed: int,
              policy: str, max_forwards: int, discard_on_exhaust: bool,
              network: Optional[LinkModel] = None,
              record_trace: bool = False):
    """Event-heap reference run.

    Returns ``(requests, result, targets, peak, depth, transfer,
    recorder)`` — ``targets[dense_idx, hop]`` records every forwarding
    choice in the order the heap consumed it, ``transfer[dense_idx]`` the
    wire time the request paid on referrals, ``peak`` the largest
    per-node admission count (sizes the fleet slot buffer: head-pointer
    rows retire slots without reusing them, so capacity tracks total
    admissions, not peak depth), ``depth`` the deepest queue observed.
    With ``record_trace`` the run additionally streams through a
    :class:`repro.telemetry.TraceRecorder` (chained ahead of the local
    hooks) and returns it; otherwise ``recorder`` is None.
    """
    requests = workload.generate(seed)
    idx = {r.rid: j for j, r in enumerate(requests)}
    targets = np.full((len(requests), max(max_forwards, 1)), -1, np.int32)
    transfer = np.zeros((len(requests),), np.float64)
    hops = {}
    depth = 0

    def on_forward(req, src, dst, now):
        h = hops.get(req.rid, 0)
        hops[req.rid] = h + 1
        targets[idx[req.rid], h] = dst.node_id
        if network is not None:
            transfer[idx[req.rid]] += network.transfer_delay(
                src.node_id, dst.node_id, req.service)

    def on_admit(req, node, now, forced):
        nonlocal depth
        depth = max(depth, len(node.queue))

    hooks = Hooks(on_forward=on_forward, on_admit=on_admit)
    recorder = None
    if record_trace:
        recorder = TraceRecorder(network=network, hooks=hooks)
        hooks = recorder.hooks
    orch = Orchestrator(topology, FastPreferentialQueue,
                        Router(topology, policy, seed=seed),
                        max_forwards=max_forwards,
                        discard_on_exhaust=discard_on_exhaust,
                        network=network,
                        hooks=hooks)
    result = orch.run(requests)
    peak = max(n.admitted for n in result.per_node)
    return requests, result, targets, peak, depth, transfer, recorder


def _host_outcomes(requests, result):
    """Per-request (outcome code, serving node) of the heap run."""
    out = np.full((len(requests),), fcore.DISCARDED, np.int32)
    served = np.full((len(requests),), -1, np.int32)
    idx = {r.rid: j for j, r in enumerate(requests)}
    for r in result.completed:
        out[idx[r.rid]] = fcore.MET if r.met_deadline else fcore.LATE
        served[idx[r.rid]] = r.served_by
    return out, served


def run_validation(scenario: str = "paper/scenario1", seed: int = 0,
                   policy: str = "random", max_forwards: int = 2,
                   discard_on_exhaust: bool = False,
                   topology: Optional[Topology] = None,
                   capacity: Optional[int] = None,
                   network: Optional[LinkModel] = None,
                   telemetry: Optional[int] = None) -> ValidationReport:
    """One (scenario, seed, policy) cross-validation cell.

    ``network`` runs BOTH engines under the link model (the host pays
    transfer delays on forward events, fleetsim defers the re-arrival
    event by the same ``(K, K)`` costs).  The exactness contract covers
    priced networks as well as the zero model — the event-time scan
    replays the heap's event interleaving exactly (DESIGN.md §7), so
    outcome, serving node and per-request wire time are all compared.

    ``telemetry`` (a bucket count) extends the contract from outcomes to
    dynamics (DESIGN.md §8): the host run streams through a
    :class:`~repro.telemetry.TraceRecorder`, a second device run carries
    the telemetry cube, and the two time-binned summaries must agree
    bucket-for-bucket — counters and occupancy exactly, derived
    integrals within f32-endpoint tolerance.  The telemetry-enabled run
    is additionally checked bit-identical to the plain run on every
    shared output (the disabled-path guarantee, from the other side).
    """
    workload = get_workload(scenario) if isinstance(scenario, str) \
        else scenario
    name = scenario if isinstance(scenario, str) else workload.name
    if topology is None:
        topology = network.topology if network is not None \
            else Topology.full_mesh(workload.n_nodes)
    if network is not None and network.n_nodes != topology.n_nodes:
        raise ValueError("network and topology disagree on node count")
    requests, result, targets, peak, depth, host_tr, recorder = _host_run(
        workload, topology, seed, policy, max_forwards, discard_on_exhaust,
        network=network, record_trace=telemetry is not None)

    if capacity is None:
        capacity = 1 << max(3, (peak + 2 - 1).bit_length())
    window = 1 << max(3, (depth + 2 - 1).bit_length())
    # scan length: one step per heap arrival event (fresh + re-arrivals),
    # sized off the host's realized forward count with generous slack —
    # event_overflow is asserted 0 below, so undersizing cannot pass
    max_events = min(len(requests) * (max_forwards + 1),
                     len(requests) + 2 * result.forwards + 256)
    reqs, _, _ = pack_requests(
        requests, payload_fn=network.payload_of if network else None)
    fleet_policy = policy if policy in DETERMINISTIC else "trace"
    m = fcore.simulate(reqs, topology_arrays(topology), fcore.SimParams.make(seed),
                       policy=fleet_policy, max_forwards=max_forwards,
                       discard_on_exhaust=discard_on_exhaust,
                       capacity=capacity, depth=window, targets=targets,
                       net=network.net_params() if network else None,
                       max_events=max_events)
    assert int(m.overflow) == 0 and int(m.window_saturation) == 0, \
        f"fleet capacity {capacity}/depth {window} saturated " \
        f"(host peak admissions {peak}, depth {depth})"
    assert int(m.event_overflow) == 0, \
        f"event plane saturated (max_events {max_events}, " \
        f"host forwards {result.forwards})"

    agreement = None
    if telemetry is not None:
        horizon = float(result.end_time)
        m_tel = fcore.simulate(
            reqs, topology_arrays(topology), fcore.SimParams.make(seed),
            policy=fleet_policy, max_forwards=max_forwards,
            discard_on_exhaust=discard_on_exhaust,
            capacity=capacity, depth=window, targets=targets,
            net=network.net_params() if network else None,
            max_events=max_events,
            telemetry=TelemetryConfig(telemetry, horizon))
        # the disabled-path guarantee, measured from the enabled side:
        # carrying the cube must not perturb a single output bit
        for fld in ("outcome", "served_by", "completion", "forwards_used",
                    "transfer_used", "met_deadline", "processed",
                    "forwards", "discarded", "overflow",
                    "window_saturation", "event_overflow"):
            a = np.asarray(getattr(m, fld))
            b = np.asarray(getattr(m_tel, fld))
            assert np.array_equal(a, b), \
                f"telemetry run perturbed {fld} (disabled-path guarantee)"
        host_sum = recorder.summary(requests, topology, telemetry, horizon)
        dev_sum = TelemetrySummary.from_frame(m_tel.telemetry)
        agreement = compare_summaries(host_sum, dev_sum)

    host_out, host_served = _host_outcomes(requests, result)
    mismatches = int(np.sum(host_out != np.asarray(m.outcome)))
    node_mismatches = int(np.sum(host_served != np.asarray(m.served_by)))
    transfer_max_err = float(np.max(np.abs(
        host_tr - np.asarray(m.transfer_used, np.float64)), initial=0.0))
    total = len(requests)
    host = dict(met_deadline=result.met_deadline, processed=result.processed,
                forwards=result.forwards, discarded=result.discarded,
                mean_response_time=result.mean_response_time,
                transfer_time=result.transfer_time)
    fleet = dict(met_deadline=int(m.met_deadline), processed=int(m.processed),
                 forwards=int(m.forwards), discarded=int(m.discarded),
                 mean_response_time=float(m.mean_response_time),
                 transfer_time=float(m.transfer_time))
    return ValidationReport(
        scenario=name, seed=seed, policy=policy, total=total,
        host=host, fleet=fleet, outcome_mismatches=mismatches,
        node_mismatches=node_mismatches, transfer_max_err=transfer_max_err,
        met_diff_pp=100.0 * abs(host["met_deadline"]
                                - fleet["met_deadline"]) / max(1, total),
        capacity=capacity, telemetry=agreement)


def main() -> List[ValidationReport]:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", nargs="*", default=[
        "paper/scenario1", "paper/scenario2", "paper/scenario3"])
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--policy", default="random")
    ap.add_argument("--discard", action="store_true")
    ap.add_argument("--net", default=None,
                    help="run both engines under a link model: 'zero' or a "
                         "priced preset (campus/metro/wan).  The exactness "
                         "contract is enforced either way — the event-time "
                         "scan replays the heap exactly under any pricing "
                         "(DESIGN.md §7)")
    ap.add_argument("--telemetry", nargs="?", type=int, const=32,
                    default=None, metavar="BUCKETS",
                    help="also enforce the telemetry contract (DESIGN.md "
                         "§8): host trace and device time-series must "
                         "agree bucket-for-bucket, and the telemetry-"
                         "enabled run must be bit-identical to the plain "
                         "one.  Optional value = bucket count (default 32)")
    args = ap.parse_args()
    reports = []
    for sc in args.scenarios:
        workload = get_workload(sc)
        network = None
        if args.net is not None:
            topo = Topology.full_mesh(workload.n_nodes)
            network = LinkModel.zero(topo) if args.net == "zero" \
                else LinkModel.preset(topo, args.net)
        for seed in range(args.seeds):
            rep = run_validation(sc, seed, policy=args.policy,
                                 discard_on_exhaust=args.discard,
                                 network=network, telemetry=args.telemetry)
            reports.append(rep)
            print(rep.row(), flush=True)
    worst = max(r.met_diff_pp for r in reports)
    n_exact = sum(r.exact for r in reports)
    violations = [r for r in reports
                  if r.met_diff_pp > 0.5
                  or r.outcome_mismatches > 0.005 * r.total
                  or r.node_mismatches > 0.005 * r.total
                  or (r.telemetry is not None and not r.telemetry.ok)]
    print(f"# {n_exact}/{len(reports)} cells exact; "
          f"worst met-rate delta {worst:.3f}pp "
          f"(contract: exact or <= 0.5pp f32-boundary flips, "
          f"DESIGN.md §5/§7; net={args.net or 'none'})")
    if violations:
        raise SystemExit(
            f"equivalence contract violated in {len(violations)} cell(s): "
            + "; ".join(v.row() for v in violations))
    return reports


if __name__ == "__main__":
    main()
