"""Cross-validation: fleetsim vs the event-heap Orchestrator.

The contract (DESIGN.md §5): on identical workloads the scan-based fleet
simulator reproduces the event heap's per-request outcomes

* **exactly** for deterministic forwarding policies (``round_robin``,
  ``batched_feasible``), and
* **exactly under trace replay** for the stochastic ones — the host run
  records every forwarding choice through ``Hooks.on_forward`` and
  fleetsim replays it (``policy="trace"``), so any dynamics divergence
  (admission, timing, tie-breaking) still surfaces as an outcome mismatch
  while the Mersenne-vs-threefry rng stream difference is factored out,

modulo float32-boundary flips: the host queue schedules in float64, the
device ledger in float32, so a request whose feasibility / deadline margin
is below f32 resolution (~1e-2 at the paper's 1e5-UT timescale) can land
on the other side of the test.  Empirically this is rare (see
EXPERIMENTS.md §Fleetsim); ``run_validation`` reports exact counts and the
per-request mismatch list so the tolerance is measured, not assumed.

    PYTHONPATH=src python -m repro.fleetsim.validate            # 3 scenarios
    PYTHONPATH=src python -m repro.fleetsim.validate --policy round_robin
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.block_queue import FastPreferentialQueue
from repro.fleetsim import core as fcore
from repro.fleetsim.arrays import pack_requests, topology_arrays
from repro.netsim import LinkModel
from repro.orchestration import (Hooks, Orchestrator, Router, Topology,
                                 Workload, get_workload)

# host policies fleetsim replays move-for-move without a trace
DETERMINISTIC = ("round_robin", "batched_feasible")


@dataclasses.dataclass
class ValidationReport:
    scenario: str
    seed: int
    policy: str
    total: int
    host: Dict[str, float]
    fleet: Dict[str, float]
    outcome_mismatches: int          # per-request outcome-code disagreements
    met_diff_pp: float               # |met-rate difference| in percent points
    capacity: int

    @property
    def exact(self) -> bool:
        return self.outcome_mismatches == 0

    def row(self) -> str:
        tag = "exact" if self.exact else \
            f"{self.outcome_mismatches} mismatches"
        return (f"{self.scenario:18s} seed={self.seed} {self.policy:16s} "
                f"met {self.host['met_deadline']:6.0f}/{self.fleet['met_deadline']:6.0f} "
                f"fwd {self.host['forwards']:6.0f}/{self.fleet['forwards']:6.0f} "
                f"disc {self.host['discarded']:5.0f}/{self.fleet['discarded']:5.0f} "
                f"dmet {self.met_diff_pp:5.3f}pp  [{tag}]")


def _host_run(workload: Workload, topology: Topology, seed: int,
              policy: str, max_forwards: int, discard_on_exhaust: bool,
              network: Optional[LinkModel] = None):
    """Event-heap reference run; returns (requests, result, targets, depth).

    ``targets[dense_idx, hop]`` records every forwarding choice in the
    order the heap consumed it; ``peak`` is the largest per-node admission
    count, which sizes the fleet slot buffer (head-pointer rows retire
    slots without reusing them, so capacity tracks total admissions, not
    peak depth).
    """
    requests = workload.generate(seed)
    idx = {r.rid: j for j, r in enumerate(requests)}
    targets = np.full((len(requests), max(max_forwards, 1)), -1, np.int32)
    hops = {}
    depth = 0

    def on_forward(req, src, dst, now):
        h = hops.get(req.rid, 0)
        hops[req.rid] = h + 1
        targets[idx[req.rid], h] = dst.node_id

    def on_admit(req, node, now, forced):
        nonlocal depth
        depth = max(depth, len(node.queue))

    orch = Orchestrator(topology, FastPreferentialQueue,
                        Router(topology, policy, seed=seed),
                        max_forwards=max_forwards,
                        discard_on_exhaust=discard_on_exhaust,
                        network=network,
                        hooks=Hooks(on_forward=on_forward,
                                    on_admit=on_admit))
    result = orch.run(requests)
    peak = max(n.admitted for n in result.per_node)
    return requests, result, targets, peak, depth


def _host_outcomes(requests, result) -> np.ndarray:
    out = np.full((len(requests),), fcore.DISCARDED, np.int32)
    idx = {r.rid: j for j, r in enumerate(requests)}
    for r in result.completed:
        out[idx[r.rid]] = fcore.MET if r.met_deadline else fcore.LATE
    return out


def run_validation(scenario: str = "paper/scenario1", seed: int = 0,
                   policy: str = "random", max_forwards: int = 2,
                   discard_on_exhaust: bool = False,
                   topology: Optional[Topology] = None,
                   capacity: Optional[int] = None,
                   network: Optional[LinkModel] = None) -> ValidationReport:
    """One (scenario, seed, policy) cross-validation cell.

    ``network`` runs BOTH engines under the link model (the host pays
    transfer delays on forward events, fleetsim folds the same ``(K, K)``
    costs into its chain scoring).  The exactness contract covers the
    zero model — a priced network is an approximation cell (the scan
    resolves a referral chain at its source step; arrivals that interleave
    a multi-hop referral in the host can diverge, DESIGN.md §6).
    """
    workload = get_workload(scenario) if isinstance(scenario, str) \
        else scenario
    name = scenario if isinstance(scenario, str) else workload.name
    if topology is None:
        topology = network.topology if network is not None \
            else Topology.full_mesh(workload.n_nodes)
    if network is not None and network.n_nodes != topology.n_nodes:
        raise ValueError("network and topology disagree on node count")
    requests, result, targets, peak, depth = _host_run(
        workload, topology, seed, policy, max_forwards, discard_on_exhaust,
        network=network)

    if capacity is None:
        capacity = 1 << max(3, (peak + 2 - 1).bit_length())
    window = 1 << max(3, (depth + 2 - 1).bit_length())
    reqs, _, _ = pack_requests(
        requests, payload_fn=network.payload_of if network else None)
    fleet_policy = policy if policy in DETERMINISTIC else "trace"
    m = fcore.simulate(reqs, topology_arrays(topology), fcore.SimParams.make(seed),
                       policy=fleet_policy, max_forwards=max_forwards,
                       discard_on_exhaust=discard_on_exhaust,
                       capacity=capacity, depth=window, targets=targets,
                       net=network.net_params() if network else None)
    assert int(m.overflow) == 0 and int(m.window_saturation) == 0, \
        f"fleet capacity {capacity}/depth {window} saturated " \
        f"(host peak admissions {peak}, depth {depth})"

    host_out = _host_outcomes(requests, result)
    mismatches = int(np.sum(host_out != np.asarray(m.outcome)))
    total = len(requests)
    host = dict(met_deadline=result.met_deadline, processed=result.processed,
                forwards=result.forwards, discarded=result.discarded,
                mean_response_time=result.mean_response_time)
    fleet = dict(met_deadline=int(m.met_deadline), processed=int(m.processed),
                 forwards=int(m.forwards), discarded=int(m.discarded),
                 mean_response_time=float(m.mean_response_time))
    return ValidationReport(
        scenario=name, seed=seed, policy=policy, total=total,
        host=host, fleet=fleet, outcome_mismatches=mismatches,
        met_diff_pp=100.0 * abs(host["met_deadline"]
                                - fleet["met_deadline"]) / max(1, total),
        capacity=capacity)


def main() -> List[ValidationReport]:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", nargs="*", default=[
        "paper/scenario1", "paper/scenario2", "paper/scenario3"])
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--policy", default="random")
    ap.add_argument("--discard", action="store_true")
    ap.add_argument("--net", default=None,
                    help="run both engines under a link model: 'zero' "
                         "(equivalence contract enforced — the netsim "
                         "machinery must reproduce the free-network "
                         "outputs exactly) or a profile name "
                         "(campus/metro/wan; report-only, the scan is an "
                         "approximation under priced networks)")
    args = ap.parse_args()
    reports = []
    for sc in args.scenarios:
        workload = get_workload(sc)
        network = None
        if args.net is not None:
            topo = Topology.full_mesh(workload.n_nodes)
            network = LinkModel.zero(topo) if args.net == "zero" \
                else LinkModel.preset(topo, args.net)
        for seed in range(args.seeds):
            rep = run_validation(sc, seed, policy=args.policy,
                                 discard_on_exhaust=args.discard,
                                 network=network)
            reports.append(rep)
            print(rep.row(), flush=True)
    worst = max(r.met_diff_pp for r in reports)
    n_exact = sum(r.exact for r in reports)
    enforce = args.net is None or args.net == "zero"
    violations = [r for r in reports
                  if r.met_diff_pp > 0.5
                  or r.outcome_mismatches > 0.005 * r.total] if enforce else []
    print(f"# {n_exact}/{len(reports)} cells exact; "
          f"worst met-rate delta {worst:.3f}pp "
          + ("(contract: exact or <= 0.5pp, DESIGN.md §5)" if enforce else
         f"(net={args.net}: approximation cells, report only — DESIGN.md §6)"))
    if violations:
        raise SystemExit(
            f"equivalence contract violated in {len(violations)} cell(s): "
            + "; ".join(v.row() for v in violations))
    return reports


if __name__ == "__main__":
    main()
