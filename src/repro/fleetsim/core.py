"""Device-resident fleet simulator: the whole deadline-aware admission +
sequential-forwarding strategy compiled end-to-end in JAX.

The event-heap :class:`~repro.orchestration.orchestrator.Orchestrator`
walks a Python heap; this module replays the *same* strategy as one
``lax.scan`` over **events**, with the entire fleet held as stacked
``(num_nodes, capacity)`` ledger arrays (the
:class:`~repro.core.jax_queue.Ledger` geometry plus per-slot absolute
deadlines and request ids) next to per-node ``head``/``busy_until``/
``load`` vectors.  One scan step = one *event* — the earliest of

* the next **fresh arrival**, streamed straight from the arrival-sorted
  request tensor through a cursor (fresh arrivals fill the host heap
  before the run, so they carry the lowest sequence numbers and win
  every timestamp tie), and
* the head of the **deferred re-arrival buffer** — a sorted, compact
  device event queue (:func:`repro.core.jax_queue.event_push` /
  ``event_pop``) holding every in-flight referral at its true wire-
  delayed arrival time, stable-inserted so equal timestamps keep push
  order, exactly the heap's ``(time, seq)`` key

— processed as a **single hop**:

1. **fast-forward** — a masked ``while_loop`` retires every completion
   due strictly before the event time (the CPU model is work-conserving,
   so the pop chain between two events is deterministic).  Rows are
   *head-pointer* ledgers: a pop clears one slot (start/end to -BIG,
   size to 0 — which keeps the whole row time-sorted and every count /
   prefix-sum valid) and bumps ``head``, so retiring costs O(nodes)
   scatters instead of shifting the (num_nodes, capacity) block;
2. **test + route** — the event's node is scored by one feasibility +
   geometry pass over its live window; an infeasible, non-exhausted
   request picks its forwarding target *now*, at true event time (loads,
   rng, the trace row, and — for ``batched_feasible`` — the fused
   per-hop ``link_cost`` mask of the :func:`repro.kernels.ops.
   event_select` kernel all reflect every earlier event), and emits a
   re-arrival event at ``t + transfer_delay`` instead of resolving the
   chain speculatively at the source step;
3. **apply** — feasible insert at the pre-computed (slot, window) pair,
   forced tail-append, or discard as ``where``-selects; an idle CPU
   short-circuits the insert (the host engine pushes then immediately
   pops — the net effect is starting the request at ``t``).

Because nothing escapes the device, :func:`simulate` jits whole and
``vmap``s over seeds and policy parameters (``SimParams``): a full paper
table — scenarios x policies x seeds — is one device call.  Equivalence
with the event heap is exact for deterministic policies and exact under
forwarding-trace replay for the stochastic ones — **under any link
pricing**, not just the zero network: deferred re-arrivals replay the
heap's interleaving of arrivals, completions and referrals event for
event (contract in DESIGN.md §7; cross-validated in fleetsim/validate.py
and tests/test_fleetsim.py / tests/test_netsim.py).

The network is a further sweep axis (``net``: :class:`repro.netsim.
NetParams` — (K, K) latency / inverse-bandwidth tensors): a referral's
wire time delays its re-arrival while the absolute deadline stays put,
consuming admission slack exactly as in the heap's netsim integration
(DESIGN.md §6).  ``net=None`` runs the same event machinery with every
hop priced 0.0, so ``NetParams.zero`` reproduces its outcomes
bit-for-bit (equivalence-guarded in tests/test_netsim.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import jax_queue as jq
from repro.fleetsim.arrays import (RequestArrays, TopologyArrays,
                                   event_bound)
from repro.kernels import ref as kref
from repro.netsim.link import NetParams
from repro.telemetry.timeline import (TelemetryConfig, TelemetryFrame,
                                      bucket_of, bucket_width,
                                      interval_histogram, telemetry_init)

POLICIES = ("random", "power_of_two", "least_loaded", "round_robin",
            "batched_feasible", "trace")

# outcome codes (per request)
PENDING, MET, LATE, DISCARDED, OVERFLOW = 0, 1, 2, 3, 4

_MET_EPS = 1e-9          # same slack as Request.met_deadline


class SimParams(NamedTuple):
    """Traced sweep axes: everything here can carry a vmap dimension."""
    seed: jnp.ndarray                  # i32 — forwarding rng stream
    sla_scale: jnp.ndarray             # f32 — multiplies relative deadlines

    @classmethod
    def make(cls, seed: int = 0, sla_scale: float = 1.0) -> "SimParams":
        return cls(seed=jnp.asarray(seed, jnp.int32),
                   sla_scale=jnp.asarray(sla_scale, jnp.float32))


# per-request terminal record, packed into ONE i32 scatter per event (the
# scan's hot loop is fusion-break bound on CPU — every scatter counts):
# bits [0,8) forwards used, bit 8 discarded, bit 9 overflow, bits [10,..)
# serving node + 1 (0 == not admitted).
_INFO_DISC, _INFO_OVF, _INFO_SERVED = 1 << 8, 1 << 9, 10


class EventState(NamedTuple):
    """The scan carry: fleet ledgers + the event plane + outcome arrays."""
    # stacked head-pointer ledgers: (K, N) block geometry + per-slot request
    # identity; live blocks of node k occupy columns [head[k], head[k]+nq[k])
    starts: jnp.ndarray
    ends: jnp.ndarray
    sizes: jnp.ndarray
    slot_rid: jnp.ndarray              # dense request index per block (i32)
    head: jnp.ndarray                  # (K,) i32 retired-slot count
    nq: jnp.ndarray                    # (K,) i32 live block count
    busy: jnp.ndarray                  # (K,) time the CPU frees
    load: jnp.ndarray                  # (K,) pending ledger work (= host
    #                                     queue.pending_work(), active excl.)
    rr: jnp.ndarray                    # () i32 round-robin pointer
    # the event plane: fresh arrivals stream through `cursor`; deferred
    # re-arrivals live in the sorted compact (B,) buffer (host-heap order)
    cursor: jnp.ndarray                # () i32 next fresh arrival index
    ev_time: jnp.ndarray               # (B,) event times, +BIG past ev_n
    ev_rid: jnp.ndarray                # (B,) i32 request dense index
    ev_meta: jnp.ndarray               # (B,) i32 node << hop_bits | hops
    ev_n: jnp.ndarray                  # () i32 buffered event count
    ev_dropped: jnp.ndarray            # () i32 pushes lost to a full buffer
    sat_events: jnp.ndarray            # () i32 events that consulted a full
    #                                    live window (undersized depth guard)
    # per-request outcome carries (events touch arbitrary requests, so none
    # of these can ride the scan's stacked outputs)
    completion: jnp.ndarray            # (R,) pop-time / idle-start scatter
    reqinfo: jnp.ndarray               # (R,) i32 packed terminal record
    transfer: jnp.ndarray              # (R,) wire time paid on referrals
    # the carried half of the telemetry plane (DESIGN.md §8).  None when
    # telemetry is disabled: a None leaf is an empty pytree, so the scan
    # carry, the jaxpr and the compiled step are bit-identical to a
    # build without these fields — the disabled path costs nothing
    # (guarded in tests/test_telemetry.py)
    tel_counts: Optional[jnp.ndarray] = None   # (K, NB, N_KINDS) i32
    tel_occ: Optional[jnp.ndarray] = None      # (NB,) i32 ev_n high water


class FleetMetrics(NamedTuple):
    """Headline aggregates + the per-request arrays they reduce."""
    total: jnp.ndarray
    processed: jnp.ndarray
    met_deadline: jnp.ndarray
    forwards: jnp.ndarray
    discarded: jnp.ndarray
    overflow: jnp.ndarray            # forced pushes dropped: no free slot
    window_saturation: jnp.ndarray   # events that consulted a full live
    #                                  window — admission may diverge from
    #                                  the host's unbounded queue; keep 0
    mean_response_time: jnp.ndarray
    end_time: jnp.ndarray
    outcome: jnp.ndarray
    completion: jnp.ndarray
    served_by: jnp.ndarray
    forwards_used: jnp.ndarray
    transfer_time: jnp.ndarray       # total wire time spent on referrals
    transfer_used: jnp.ndarray       # (R,) per-request wire time
    event_overflow: jnp.ndarray      # events dropped (full buffer) or left
    #                                  unprocessed at max_events; keep 0
    telemetry: Optional[TelemetryFrame] = None   # the time-binned cube;
    #                                  None unless simulate(telemetry=...)

    @property
    def met_rate(self):
        return self.met_deadline / jnp.maximum(1, self.total)


# ---------------------------------------------------------------------------
# fast-forward: retire completions due strictly before t (work-conserving
# pop chain), recording outcomes by slot rid.  Also the drain loop (t=inf).
# ---------------------------------------------------------------------------
def _retire(state: EventState, t, R: int) -> EventState:
    K, N = state.starts.shape
    rows = jnp.arange(K)

    def cond(s):
        return jnp.any((s.busy < t) & (s.nq > 0))

    def body(s):
        mask = (s.busy < t) & (s.nq > 0)
        h = jnp.minimum(s.head, N - 1)
        head_size = s.sizes[rows, h]
        new_busy = jnp.where(mask, s.busy + head_size, s.busy)
        rid = jnp.where(mask, s.slot_rid[rows, h], R)   # R => dropped

        def clear(a, v):
            return a.at[rows, h].set(jnp.where(mask, v, a[rows, h]))

        return s._replace(
            # -BIG keeps the retired prefix below every live value, so the
            # row stays globally sorted and counts/prefix sums stay valid
            starts=clear(s.starts, -jq.BIG),
            ends=clear(s.ends, -jq.BIG),
            sizes=clear(s.sizes, 0.0),
            head=s.head + mask,
            nq=s.nq - mask,
            busy=new_busy,
            load=s.load - jnp.where(mask, head_size, 0.0),
            completion=s.completion.at[rid].set(new_busy, mode="drop"),
        )

    return jax.lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# routing policies: pure selects over (load, adjacency, rng, trace row).
# Consulted at true event time — every earlier arrival, completion and
# referral has already mutated the state the policy reads, exactly like the
# host Router called from the heap's forward event.
# ---------------------------------------------------------------------------
def _route_next(policy: str, topo: TopologyArrays, load, cur, key, hop,
                tgt_row, feas_all, rr):
    """Forwarding target of ``cur`` at hop ``hop`` (traced); returns
    ``(next_node, advanced_rr)``.  Callers commit ``advanced_rr`` only when
    the forward really happens (host Router semantics: the round-robin
    pointer moves per ``choose()`` call)."""
    deg = topo.degree[cur]
    K = topo.adj.shape[0]
    if policy == "trace":
        M = tgt_row.shape[0]
        return jnp.maximum(tgt_row[jnp.minimum(hop, M - 1)], 0), rr
    if policy == "round_robin":
        # stable-id pointer: probe rr, rr+1, ... (mod K), skip non-neighbors;
        # the pointer advances past the chosen probe (host Router semantics)
        offs = jnp.arange(K)
        cands = (rr + offs) % K
        off = jnp.argmax(topo.adj[cur][cands])
        return cands[off], (rr + off + 1) % K
    if policy == "least_loaded":
        # deterministic variant: ties break to the lowest node id (the host
        # router flips a coin; documented in DESIGN.md §5)
        return jnp.argmin(jnp.where(topo.adj[cur], load, jnp.inf)), rr
    if policy == "batched_feasible":
        # least-loaded neighbor that can still admit (cross-node mask from
        # the fused event_select scoring); least-loaded fallback when nobody
        # can — identical tie-breaking to the host router (lowest id)
        ok = topo.adj[cur] & feas_all
        best_ok = jnp.argmin(jnp.where(ok, load, jnp.inf))
        best_any = jnp.argmin(jnp.where(topo.adj[cur], load, jnp.inf))
        return jnp.where(jnp.any(ok), best_ok, best_any), rr
    kh = jax.random.fold_in(key, hop)
    if policy == "random":
        u = jax.random.uniform(kh)
        pick = jnp.minimum((u * deg).astype(jnp.int32),
                           jnp.maximum(deg - 1, 0))
        return topo.neighbors[cur, pick], rr
    if policy == "power_of_two":
        k1, k2 = jax.random.split(kh)
        i1 = jnp.minimum((jax.random.uniform(k1) * deg).astype(jnp.int32),
                         jnp.maximum(deg - 1, 0))
        i2 = jnp.minimum(
            (jax.random.uniform(k2) * (deg - 1)).astype(jnp.int32),
            jnp.maximum(deg - 2, 0))
        i2 = jnp.where(i2 >= i1, i2 + 1, i2)        # sample w/o replacement
        a = topo.neighbors[cur, i1]
        b = topo.neighbors[cur, jnp.minimum(i2, jnp.maximum(deg - 1, 0))]
        two = jnp.where(load[a] <= load[b], a, b)
        return jnp.where(deg <= 1, topo.neighbors[cur, 0], two), rr
    raise ValueError(f"unknown fleetsim policy {policy!r}; "
                     f"options: {sorted(POLICIES)}")


# ---------------------------------------------------------------------------
# the scan step: one event end-to-end (select, retire, test, route, apply)
# ---------------------------------------------------------------------------
def _estep(state: EventState, _, *, topo: TopologyArrays, key, policy: str,
           max_forwards: int, discard_on_exhaust: bool, capacity: int,
           depth: int, use_pallas: bool, R: int, use_network: bool,
           net: Optional[NetParams], fresh_cols, rear_cols, targets,
           zero_net, hop_bits: int, tel_buckets: Optional[int] = None,
           tel_width=None) -> Tuple[EventState, None]:
    K = topo.speeds.shape[0]
    W = depth
    dt = state.busy.dtype

    # -- the two candidate events: next fresh arrival vs re-arrival head.
    # Per-request constants ride pre-packed row matrices so each candidate
    # costs ONE gather (the scan is fusion-break bound on CPU)
    with jax.named_scope("fleetsim.event_pop"):
        avail_a = state.cursor < R
        ci = jnp.minimum(state.cursor, R - 1)
        rid_b = state.ev_rid[0]
        meta_b = state.ev_meta[0]
        node_b = meta_b >> hop_bits
        hops_b = meta_b & ((1 << hop_bits) - 1)
        fa = fresh_cols[ci]                  # (arrival, origin, d, p, pay)
        fb = rear_cols[rid_b]                # (d, p, pay)
        origin_a = fa[1].astype(jnp.int32)
        cand_a = (fa[0], origin_a, fa[2], fa[3], fa[4], avail_a)
        cand_b = (state.ev_time[0], node_b, fb[0], fb[1], fb[2],
                  state.ev_n > 0)

        # plain-jnp merge: fresh wins timestamp ties (the host heap numbers
        # every fresh arrival before the run — lower seq than any mid-run
        # push), the buffer orders re-arrivals by stable (time, seq) insert
        take_fresh = avail_a & ((cand_a[0] <= cand_b[0]) | ~cand_b[5])
        t = jnp.where(take_fresh, cand_a[0], cand_b[0])
        cur = jnp.where(take_fresh, cand_a[1], cand_b[1])

        live = avail_a | cand_b[5]
        rid = jnp.where(take_fresh, ci, rid_b)
        hops = jnp.where(take_fresh, 0, hops_b)
        d = jnp.where(take_fresh, cand_a[2], cand_b[2])
        p = jnp.where(take_fresh, cand_a[3], cand_b[3])
        pay = jnp.where(take_fresh, cand_a[4], cand_b[4])

        # -- consume the event: bump the cursor or pop the buffer head ----
        ev_time, (ev_rid, ev_meta), ev_n = jq.event_pop(
            state.ev_time, (state.ev_rid, state.ev_meta),
            state.ev_n, live & ~take_fresh)
        state = state._replace(
            cursor=state.cursor + take_fresh.astype(jnp.int32),
            ev_time=ev_time, ev_rid=ev_rid, ev_meta=ev_meta, ev_n=ev_n)

    # -- retire completions due strictly before the event (on a dead step
    # t is +BIG, which simply starts the final drain early — harmless).
    # Everything below — the fused scoring included — must see the
    # POST-retire ledgers: the host pops every completion due before `t`
    # ahead of the admission test, and a stale not-yet-retired block would
    # inflate the pending-work sum and flip verdicts.
    with jax.named_scope("fleetsim.retire"):
        state = _retire(state, t, R)
    ps = p / topo.speeds                                    # (K,) scaled
    cpu_free_c = jnp.maximum(t, state.busy[cur])

    feas_all = j_all = cap_all = None
    if policy == "batched_feasible":
        # (scope set inside the kernels.ops wrappers: "kernels.event_select")
        # the event_select kernel's slot in the step: the two-way merge and
        # the per-hop link_cost candidate mask fused into one pass over the
        # whole fleet's live windows.  The kernel re-derives the merge from
        # the same candidate scalars (bit-identical to the jnp merge above,
        # and load-bearing inside: the selected node picks which latency /
        # inverse-bandwidth row the scoring reads).
        w0_all = jnp.clip(state.head, 0, capacity - W)
        cols = w0_all[:, None] + jnp.arange(W)[None, :]
        win_all = lambda a: jnp.take_along_axis(a, cols, axis=1)
        hrel_all = state.head - w0_all
        lat, ibw = (net.latency, net.inv_bw) if use_network \
            else (zero_net, zero_net)
        if use_pallas:
            from repro.kernels import ops as kops
            sel = kops.event_select(
                *cand_a, *cand_b, win_all(state.starts),
                win_all(state.ends), win_all(state.sizes), state.nq,
                hrel_all, topo.speeds, state.busy, lat, ibw)
        else:
            sel = kref.event_select_ref(
                *cand_a, *cand_b, win_all(state.starts),
                win_all(state.ends), win_all(state.sizes), state.nq,
                hrel_all, topo.speeds, state.busy, lat, ibw)
        take_fresh, t, cur, feas_all, _, j_all, cap_all, _ = sel

    # -- admission test at the event's node -------------------------------
    with jax.named_scope("fleetsim.feasibility"):
        w0c = jnp.clip(state.head[cur], 0, capacity - W)
        hrel_c = state.head[cur] - w0c

        def win_row(buf):
            return jax.lax.dynamic_slice(buf, (cur, w0c), (1, W))[0]

        starts_w, ends_w, sizes_w = (win_row(state.starts),
                                     win_row(state.ends),
                                     win_row(state.sizes))
        if policy == "batched_feasible":
            # the fused pass already scored every node — including `cur`
            # itself at its true arrival (zero net diagonal); gather its
            # verdict
            ok = feas_all[cur]
            j, cap = j_all[cur], cap_all[cur]
        else:
            okv, jv, capv, _ = kref.fleet_search_ref(
                starts_w[None], ends_w[None], sizes_w[None],
                state.nq[cur][None], ps[cur][None], d, cpu_free_c[None],
                hrel_c[None])
            ok, j, cap = okv[0], jv[0], capv[0]

    # -- decide: admit / forward / force / discard ------------------------
    exhausted = (hops >= max_forwards) | (topo.degree[cur] == 0)
    feas_evt = live & ok
    forced_req = live & ~ok & exhausted & (not discard_on_exhaust)
    disc_evt = live & ~ok & exhausted & discard_on_exhaust
    fwd = live & ~ok & ~exhausted

    # -- forward: pick the target NOW (true event time) and defer the
    # re-arrival to t + transfer_delay via a stable sorted insert ---------
    with jax.named_scope("fleetsim.route"):
        kreq = jax.random.fold_in(key, rid) \
            if policy in ("random", "power_of_two") else None
        tgt_row = targets[rid] if policy == "trace" else None
        nxt, rr_adv = _route_next(policy, topo, state.load, cur, kreq, hops,
                                  tgt_row, feas_all, state.rr)
        if use_network:
            # the hop's wire cost — latency plus frame serialization
            # (DESIGN.md §6) — as two scalar gathers
            delay = net.latency[cur, nxt] + pay * net.inv_bw[cur, nxt]
        else:
            delay = jnp.zeros((), dt)
        ev_time, (ev_rid, ev_meta), ev_n, dropped = jq.event_push(
            state.ev_time, (state.ev_rid, state.ev_meta),
            state.ev_n, t + delay, (rid, (nxt << hop_bits) | (hops + 1)),
            fwd)
        state = state._replace(
            ev_time=ev_time, ev_rid=ev_rid, ev_meta=ev_meta,
            ev_n=ev_n,
            ev_dropped=state.ev_dropped + dropped.astype(jnp.int32))
        if policy == "round_robin":
            state = state._replace(rr=jnp.where(fwd, rr_adv, state.rr))

    # -- apply at cur, within its window (jax_queue.insert_at — the shared
    # closed-form cascade — with the pre-computed search results) ---------
    with jax.named_scope("fleetsim.admission"):
        room = hrel_c + state.nq[cur] < W
        forced_ok = forced_req & room
        ovf_evt = forced_req & ~room
        # a consulted node whose live window is exhausted can diverge from
        # the host's unbounded queue even on the feasible path (its
        # admission test reports "no room" where the host might admit) —
        # surface it
        sat_evt = live & (hrel_c + state.nq[cur] >= W)
        idle = state.busy[cur] < t
        sr_w = win_row(state.slot_rid)
        n_starts, n_ends, n_sizes, admitted, (n_sr,) = jq.insert_at(
            starts_w, ends_w, sizes_w, hrel_c, state.nq[cur], feas_evt,
            forced_ok, j, cap, ps[cur], cpu_free_c, meta=(sr_w,),
            meta_vals=(rid,))

    # idle CPU: the host engine pushes then immediately pops — net effect is
    # the request starts at its (wire-delayed) arrival and never enters
    # the ledger
    start_now = admitted & idle
    queue_it = admitted & ~idle
    c_now = t + ps[cur]

    def put(buf, new, old):
        return jax.lax.dynamic_update_slice(
            buf, jnp.where(queue_it, new, old)[None, :], (cur, w0c))

    # the packed terminal record: one (R,) scatter instead of four
    with jax.named_scope("fleetsim.scatter"):
        terminal = admitted | disc_evt | ovf_evt
        info = (hops
                + jnp.where(disc_evt, _INFO_DISC, 0)
                + jnp.where(ovf_evt, _INFO_OVF, 0)
                + jnp.where(admitted, (cur + 1) << _INFO_SERVED, 0))
        rid_if = lambda flag: jnp.where(flag, rid, R)       # R => dropped
        state = state._replace(
            starts=put(state.starts, n_starts, starts_w),
            ends=put(state.ends, n_ends, ends_w),
            sizes=put(state.sizes, n_sizes, sizes_w),
            slot_rid=put(state.slot_rid, n_sr, sr_w),
            nq=state.nq.at[cur].add(queue_it.astype(jnp.int32)),
            load=state.load.at[cur].add(jnp.where(queue_it, ps[cur], 0.0)),
            busy=state.busy.at[cur].set(
                jnp.where(start_now, c_now, state.busy[cur])),
            sat_events=state.sat_events + sat_evt.astype(jnp.int32),
            completion=state.completion.at[rid_if(start_now)].set(
                c_now, mode="drop"),
            reqinfo=state.reqinfo.at[rid_if(terminal)].set(info,
                                                           mode="drop"),
        )
        if use_network:
            state = state._replace(
                transfer=state.transfer.at[rid_if(fwd)].add(delay,
                                                            mode="drop"))

    if tel_buckets is not None:
        # the carried half of the telemetry cube (DESIGN.md §8): one
        # 5-vector scatter-add of the event kinds at (node, bucket) and
        # one high-water max of the re-arrival buffer's live count.  On a
        # dead step every flag is False and ev_n is 0, so both updates
        # are no-ops (the +BIG event time clips into the last bucket on
        # the float side — no int overflow)
        with jax.named_scope("fleetsim.telemetry"):
            b = bucket_of(t, tel_width, tel_buckets)
            kinds = jnp.stack([
                (live & take_fresh).astype(jnp.int32),
                (live & ~take_fresh).astype(jnp.int32),
                fwd.astype(jnp.int32),
                (disc_evt | ovf_evt).astype(jnp.int32),
                admitted.astype(jnp.int32)])
            state = state._replace(
                tel_counts=state.tel_counts.at[cur, b].add(kinds),
                tel_occ=state.tel_occ.at[b].max(state.ev_n))
    return state, None


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("policy", "max_forwards", "discard_on_exhaust",
                              "capacity", "depth", "use_pallas",
                              "use_network", "max_events", "event_buf",
                              "tel_buckets", "tel_horizon"))
def _simulate(reqs: RequestArrays, topo: TopologyArrays, params: SimParams,
              targets: jnp.ndarray, net: Optional[NetParams] = None, *,
              policy: str, max_forwards: int, discard_on_exhaust: bool,
              capacity: int, depth: int, use_pallas: bool,
              use_network: bool = False,
              max_events: Optional[int] = None,
              event_buf: Optional[int] = None,
              tel_buckets: Optional[int] = None,
              tel_horizon: Optional[float] = None) -> FleetMetrics:
    R = reqs.arrival.shape[0]
    K = topo.speeds.shape[0]
    N = capacity
    dt = reqs.arrival.dtype
    E = event_bound(R, max_forwards) if max_events is None else max_events
    B = min(R, 1024) if event_buf is None else event_buf
    if max_forwards >= (1 << 8):     # packed reqinfo holds hops in 8 bits
        raise ValueError("max_forwards must be < 256 (packed terminal "
                         f"record), got {max_forwards}")
    hop_bits = max(max_forwards + 1, 2).bit_length()
    state = EventState(
        starts=jnp.full((K, N), jq.BIG, dt),
        ends=jnp.full((K, N), jq.BIG, dt),
        sizes=jnp.zeros((K, N), dt),
        slot_rid=jnp.zeros((K, N), jnp.int32),
        head=jnp.zeros((K,), jnp.int32),
        nq=jnp.zeros((K,), jnp.int32),
        busy=jnp.zeros((K,), dt),
        load=jnp.zeros((K,), dt),
        rr=jnp.zeros((), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        ev_time=jnp.full((B,), jq.BIG, dt),
        ev_rid=jnp.zeros((B,), jnp.int32),
        ev_meta=jnp.zeros((B,), jnp.int32),
        ev_n=jnp.zeros((), jnp.int32),
        ev_dropped=jnp.zeros((), jnp.int32),
        sat_events=jnp.zeros((), jnp.int32),
        completion=jnp.zeros((R,), dt),
        reqinfo=jnp.zeros((R,), jnp.int32),
        transfer=jnp.zeros((R,), dt),
    )
    tel_width = None
    if tel_buckets is not None:
        if tel_horizon is None:
            raise ValueError("telemetry needs a horizon (TelemetryConfig "
                             "carries both; got tel_buckets without "
                             "tel_horizon)")
        # the shared bucket contract (DESIGN.md §8): width computed ONCE
        # in f32 on the host so both engines bin with bit-identical
        # arithmetic
        tel_width = jnp.asarray(bucket_width(tel_horizon, tel_buckets), dt)
        tel_counts0, tel_occ0 = telemetry_init(K, tel_buckets)
        state = state._replace(tel_counts=tel_counts0, tel_occ=tel_occ0)
    key = jax.random.PRNGKey(params.seed)
    d_abs = reqs.arrival + reqs.rel_deadline * params.sla_scale
    payload = (reqs.payload if reqs.payload is not None
               else jnp.zeros_like(reqs.arrival))
    # per-request constants packed into row matrices: one gather per
    # candidate per step instead of five (origin rides as f32 — exact for
    # any node id below 2^24)
    fresh_cols = jnp.stack([reqs.arrival, reqs.origin.astype(dt), d_abs,
                            reqs.proc, payload], axis=1)
    rear_cols = jnp.stack([d_abs, reqs.proc, payload], axis=1)
    step = functools.partial(
        _estep, topo=topo, key=key, policy=policy, max_forwards=max_forwards,
        discard_on_exhaust=discard_on_exhaust, capacity=capacity,
        depth=depth, use_pallas=use_pallas, R=R, use_network=use_network,
        net=net, fresh_cols=fresh_cols, rear_cols=rear_cols,
        targets=targets, zero_net=jnp.zeros((K, K), dt), hop_bits=hop_bits,
        tel_buckets=tel_buckets, tel_width=tel_width)
    state, _ = jax.lax.scan(step, state, None, length=E)
    unprocessed = (R - state.cursor) + state.ev_n
    state = _retire(state, jnp.asarray(jnp.inf, dt), R)     # drain

    # unpack the per-request terminal records
    info = state.reqinfo
    nfwd = info & ((1 << 8) - 1)
    disc = (info & _INFO_DISC) != 0
    ovf = (info & _INFO_OVF) != 0
    served_by = (info >> _INFO_SERVED) - 1
    completion = state.completion
    has_c = completion > 0
    met = has_c & (completion <= d_abs + _MET_EPS)
    outcome = jnp.where(
        disc, DISCARDED,
        jnp.where(ovf, OVERFLOW,
                  jnp.where(met, MET, jnp.where(has_c, LATE, PENDING))))
    n_proc = jnp.sum(has_c)
    resp = jnp.sum(jnp.where(has_c, completion - reqs.arrival, 0.0))
    last_arrival = jnp.max(reqs.arrival, initial=0.0)
    end_time = jnp.maximum(jnp.max(completion, initial=0.0), last_arrival)
    telemetry = None
    if tel_buckets is not None:
        # the derived half: queue depth and CPU busy time need no scan
        # carry at all — every served request's ledger interval
        # [admit, start) and service interval [start, completion) is
        # reconstructible from the terminal arrays, so the integrals are
        # two post-scan scatter-adds over the request axis
        with jax.named_scope("fleetsim.telemetry"):
            served = served_by >= 0
            ps_served = reqs.proc / topo.speeds[jnp.clip(served_by, 0,
                                                         K - 1)]
            admit_t = reqs.arrival + state.transfer
            start_t = completion - ps_served
            depth = interval_histogram(admit_t, start_t, served_by, served,
                                       K, tel_width, tel_buckets)
            busy = interval_histogram(start_t, completion, served_by,
                                      served, K, tel_width, tel_buckets)
            telemetry = TelemetryFrame(
                counts=state.tel_counts,
                queue_depth=depth / tel_width,
                busy_time=busy,
                occupancy_hwm=state.tel_occ,
                bucket_width=tel_width)
    return FleetMetrics(
        total=jnp.int32(R),
        processed=n_proc.astype(jnp.int32),
        met_deadline=jnp.sum(met).astype(jnp.int32),
        forwards=jnp.sum(nfwd).astype(jnp.int32),
        discarded=jnp.sum(disc).astype(jnp.int32),
        overflow=jnp.sum(ovf).astype(jnp.int32),
        window_saturation=state.sat_events,
        mean_response_time=resp / jnp.maximum(1, n_proc),
        end_time=end_time,
        outcome=outcome,
        completion=completion,
        served_by=served_by,
        forwards_used=nfwd,
        transfer_time=jnp.sum(state.transfer),
        transfer_used=state.transfer,
        event_overflow=(state.ev_dropped + unprocessed).astype(jnp.int32),
        telemetry=telemetry,
    )


def simulate(reqs: RequestArrays, topo: TopologyArrays,
             params: Optional[SimParams] = None, *, policy: str = "random",
             max_forwards: int = 2, discard_on_exhaust: bool = False,
             capacity: int = 256, depth: Optional[int] = None,
             targets: Optional[jnp.ndarray] = None,
             use_pallas: bool = False,
             net: Optional[NetParams] = None,
             max_events: Optional[int] = None,
             event_buf: Optional[int] = None,
             telemetry: Optional[TelemetryConfig] = None) -> FleetMetrics:
    """Run the full fleet simulation as one device call.

    ``reqs``/``topo`` come from :mod:`repro.fleetsim.arrays` (or
    ``Workload.to_arrays()``); ``params`` carries the traced sweep axes.
    For (seeds x thresholds) sweeps, vmap :func:`simulate_fn` — every array
    argument takes a leading batch dimension, nothing leaves the device
    between sweep points.  ``capacity`` is the per-node slot-buffer width;
    each block occupies one slot for the whole run (head-pointer rows), so
    size it at the node's total admission count, not its peak depth.
    ``depth`` (default ``capacity``) is the live-window width the per-step
    math runs over — size it at peak queue depth + slack; smaller depth =
    faster steps.  ``max_events`` bounds the scan length (default
    ``R * (max_forwards + 1)``, the exact worst case — every request
    forwarded to exhaustion; size it at ``R + expected forwards + slack``
    for faster runs) and ``event_buf`` the in-flight re-arrival buffer
    (default ``min(R, 1024)``).  Undersizing any of the four is never
    silent: a forced push that finds no free slot is reported in
    ``metrics.overflow``, a request that merely *consulted* a node with
    an exhausted window counts into ``metrics.window_saturation``, and a
    re-arrival that could not be buffered or processed counts into
    ``metrics.event_overflow`` — size so all three stay 0.  ``targets``
    replays recorded forwarding choices (policy="trace", shape
    (R, max_forwards)).

    ``net`` (a :class:`repro.netsim.NetParams`) prices every referral
    hop: the wire time ``latency[u, v] + payload · inv_bw[u, v]`` defers
    the request's re-arrival event to ``t + transfer_delay`` while its
    absolute deadline stays put, consuming admission slack (DESIGN.md
    §6).  Outcomes are exact against the event-heap Orchestrator under
    any pricing — the event-time scan replays the heap's interleaving of
    arrivals, completions and referrals event for event (DESIGN.md §7).
    ``net=None`` prices every hop 0.0 through the same machinery, and
    ``NetParams.zero`` reproduces its outcomes bit-for-bit
    (equivalence-guarded).

    ``telemetry`` (a :class:`repro.telemetry.TelemetryConfig`) turns on
    the device-side time series: ``metrics.telemetry`` becomes a
    :class:`~repro.telemetry.TelemetryFrame` binning the run into
    ``n_buckets`` buckets over ``[0, horizon)`` — per-node event-kind
    counters, time-averaged queue depth, CPU busy time, and the
    re-arrival buffer's occupancy high-water mark (DESIGN.md §8).  The
    frame costs two extra scan carries; with ``telemetry=None`` (the
    default) those carries are ``None`` pytree leaves that compile out
    entirely — the hot path is bit-identical to a build without
    telemetry.  Both config fields are static: each (n_buckets, horizon)
    pair compiles once.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown fleetsim policy {policy!r}; "
                         f"options: {sorted(POLICIES)}")
    params = params if params is not None else SimParams.make()
    reqs = RequestArrays(
        *(jnp.asarray(a) for a in reqs[:5]),
        payload=None if reqs.payload is None else jnp.asarray(reqs.payload))
    topo = TopologyArrays(*(jnp.asarray(a) for a in topo))
    if targets is None:
        targets = jnp.full((reqs.arrival.shape[0], max(max_forwards, 1)),
                           -1, jnp.int32)
    depth = capacity if depth is None else min(depth, capacity)
    use_network = net is not None
    if use_network:
        if reqs.payload is None:
            # never silently drop the serialization half of the wire cost
            raise ValueError(
                "net= requires RequestArrays.payload (use pack_requests / "
                "Workload.to_arrays, or pass payload=zeros explicitly for "
                "a latency-only network)")
        net = NetParams(*(jnp.asarray(a, jnp.float32) for a in net))
    tel_buckets = tel_horizon = None
    if telemetry is not None:
        tel_buckets = int(telemetry.n_buckets)
        tel_horizon = float(telemetry.horizon)
        if tel_buckets < 1 or not tel_horizon > 0:
            raise ValueError(f"telemetry needs n_buckets >= 1 and a "
                             f"positive horizon, got {telemetry}")
    return _simulate(reqs, topo, params, jnp.asarray(targets, jnp.int32),
                     net, policy=policy, max_forwards=max_forwards,
                     discard_on_exhaust=discard_on_exhaust,
                     capacity=capacity, depth=depth, use_pallas=use_pallas,
                     use_network=use_network, max_events=max_events,
                     event_buf=event_buf, tel_buckets=tel_buckets,
                     tel_horizon=tel_horizon)


def simulate_fn(*, policy: str = "random", max_forwards: int = 2,
                discard_on_exhaust: bool = False, capacity: int = 256,
                depth: Optional[int] = None, use_pallas: bool = False,
                network: bool = False, max_events: Optional[int] = None,
                event_buf: Optional[int] = None,
                telemetry: Optional[TelemetryConfig] = None):
    """The jitted simulator with statics bound — the thing to ``jax.vmap``.

    Signature of the returned function:
    ``(reqs: RequestArrays, topo: TopologyArrays, params: SimParams,
    targets: (R, max_forwards) i32) -> FleetMetrics``; map any subset of
    arguments, e.g.::

        run = fleetsim.simulate_fn(policy="least_loaded")
        sweep = jax.vmap(run, in_axes=(None, None, SimParams(0, None), None))
        metrics = sweep(reqs, topo, SimParams.make(jnp.arange(32), 1.0), tgt)

    With ``network=True`` the returned function takes a fifth argument —
    a :class:`repro.netsim.NetParams` — making the network itself a sweep
    axis::

        run = fleetsim.simulate_fn(policy="least_loaded", network=True)
        grid = jax.vmap(run, in_axes=(None, None, None, None, 0))
        metrics = grid(reqs, topo, params, tgt, stacked_net_params)

    ``max_events``/``event_buf`` size the event-time scan (see
    :func:`simulate`; defaults: the exact worst-case scan bound, and a
    ``min(R, 1024)``-slot re-arrival buffer).  A sweep's sizing must
    cover its heaviest cell — undersizing surfaces in
    ``metrics.event_overflow``, never silently, so check it across the
    whole sweep.

    ``telemetry=TelemetryConfig(nb, horizon)`` threads the device time
    series through every mapped cell: under vmap the returned
    ``metrics.telemetry`` is a *stacked* frame — counts of shape
    ``(sweep, K, nb, N_KINDS)`` and so on — one telemetry cube per sweep
    point from a single device call (see :func:`simulate`).
    """
    tel_buckets = tel_horizon = None
    if telemetry is not None:
        tel_buckets = int(telemetry.n_buckets)
        tel_horizon = float(telemetry.horizon)
    return functools.partial(
        _simulate, policy=policy, max_forwards=max_forwards,
        discard_on_exhaust=discard_on_exhaust, capacity=capacity,
        depth=capacity if depth is None else min(depth, capacity),
        use_pallas=use_pallas, use_network=network, max_events=max_events,
        event_buf=event_buf, tel_buckets=tel_buckets,
        tel_horizon=tel_horizon)
