"""Device-resident fleet simulator: the whole deadline-aware admission +
sequential-forwarding strategy compiled end-to-end in JAX.

The event-heap :class:`~repro.orchestration.orchestrator.Orchestrator`
walks a Python heap; this module replays the *same* strategy as one
``lax.scan`` over the arrival-sorted request tensor, with the entire fleet
held as stacked ``(num_nodes, capacity)`` ledger arrays (the
:class:`~repro.core.jax_queue.Ledger` geometry plus per-slot absolute
deadlines and request ids) next to per-node ``head``/``busy_until``/
``load`` vectors.  One scan step = one request:

1. **fast-forward** — a masked ``while_loop`` retires every completion due
   strictly before the arrival (the CPU model is work-conserving, so the
   pop chain between two arrivals is deterministic).  Rows are
   *head-pointer* ledgers: a pop clears one slot (start/end to -BIG, size
   to 0 — which keeps the whole row time-sorted and every count /
   prefix-sum valid) and bumps ``head``, so retiring costs O(nodes)
   scatters instead of shifting the (num_nodes, capacity) block;
2. **forward chain** — ``max_forwards`` is static, so the paper's
   sequential forwarding unrolls into the `M+1` candidate nodes, computed
   *speculatively* before any admission test (routing depends only on
   loads / rng / the trace row — never on mid-chain ledger state, which
   cannot change until a request is admitted; the round-robin pointer is
   resolved afterwards from the realized forward count).  The candidates'
   ledger rows are gathered once and scored by a single vectorized
   feasibility pass (:func:`repro.kernels.ref.fleet_search_ref`, the same
   math as the Pallas fleet-feasibility kernel), and the stop position is
   one ``argmax`` over the feasible/exhausted mask;
3. **apply** — feasible insert at the pre-computed (slot, window) pair,
   forced tail-append, or discard as ``where``-selects; an idle CPU
   short-circuits the insert (the host engine pushes then immediately
   pops — the net effect is starting the request at ``t``).

Because nothing escapes the device, :func:`simulate` jits whole and
``vmap``s over seeds and policy parameters (``SimParams``): a full paper
table — scenarios x policies x seeds — is one device call.  Equivalence
with the event heap is exact for deterministic policies and exact under
forwarding-trace replay for the stochastic ones (tie-break contract in
DESIGN.md §5; cross-validated in fleetsim/validate.py and
tests/test_fleetsim.py).

The network is a further sweep axis (``net``: :class:`repro.netsim.
NetParams` — (K, K) latency / inverse-bandwidth tensors): the
speculative forward chain carries wire-delayed arrival times, so a
referral consumes admission slack exactly as in the event heap's
netsim integration (DESIGN.md §6).  ``net=None`` compiles the exact
pre-netsim step; ``NetParams.zero`` reproduces its outcomes bit-for-bit
(equivalence-guarded in tests/test_netsim.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import jax_queue as jq
from repro.fleetsim.arrays import RequestArrays, TopologyArrays
from repro.kernels import ref as kref
from repro.netsim.link import NetParams

POLICIES = ("random", "power_of_two", "least_loaded", "round_robin",
            "batched_feasible", "trace")

# outcome codes (per request)
PENDING, MET, LATE, DISCARDED, OVERFLOW = 0, 1, 2, 3, 4

_MET_EPS = 1e-9          # same slack as Request.met_deadline


class SimParams(NamedTuple):
    """Traced sweep axes: everything here can carry a vmap dimension."""
    seed: jnp.ndarray                  # i32 — forwarding rng stream
    sla_scale: jnp.ndarray             # f32 — multiplies relative deadlines

    @classmethod
    def make(cls, seed: int = 0, sla_scale: float = 1.0) -> "SimParams":
        return cls(seed=jnp.asarray(seed, jnp.int32),
                   sla_scale=jnp.asarray(sla_scale, jnp.float32))


class FleetState(NamedTuple):
    # stacked head-pointer ledgers: (K, N) block geometry + per-slot request
    # identity; live blocks of node k occupy columns [head[k], head[k]+nq[k])
    starts: jnp.ndarray
    ends: jnp.ndarray
    sizes: jnp.ndarray
    slot_rid: jnp.ndarray              # dense request index per block (i32)
    head: jnp.ndarray                  # (K,) i32 retired-slot count
    nq: jnp.ndarray                    # (K,) i32 live block count
    busy: jnp.ndarray                  # (K,) time the CPU frees
    load: jnp.ndarray                  # (K,) pending ledger work (= host
    #                                     queue.pending_work(), active excl.)
    rr: jnp.ndarray                    # () i32 round-robin pointer
    # the one (R,) carry: completion times scattered at pop time (pops hit
    # arbitrary earlier requests, so this cannot ride the scan's stacked
    # outputs like every per-request decision does)
    completion: jnp.ndarray


class FleetMetrics(NamedTuple):
    """Headline aggregates + the per-request arrays they reduce."""
    total: jnp.ndarray
    processed: jnp.ndarray
    met_deadline: jnp.ndarray
    forwards: jnp.ndarray
    discarded: jnp.ndarray
    overflow: jnp.ndarray            # forced pushes dropped: no free slot
    window_saturation: jnp.ndarray   # requests that consulted a full live
    #                                  window — admission may diverge from
    #                                  the host's unbounded queue; keep 0
    mean_response_time: jnp.ndarray
    end_time: jnp.ndarray
    outcome: jnp.ndarray
    completion: jnp.ndarray
    served_by: jnp.ndarray
    forwards_used: jnp.ndarray

    @property
    def met_rate(self):
        return self.met_deadline / jnp.maximum(1, self.total)


# ---------------------------------------------------------------------------
# fast-forward: retire completions due strictly before t (work-conserving
# pop chain), recording outcomes by slot rid.  Also the drain loop (t=inf).
# ---------------------------------------------------------------------------
def _retire(state: FleetState, t, R: int) -> FleetState:
    K, N = state.starts.shape
    rows = jnp.arange(K)

    def cond(s):
        return jnp.any((s.busy < t) & (s.nq > 0))

    def body(s):
        mask = (s.busy < t) & (s.nq > 0)
        h = jnp.minimum(s.head, N - 1)
        head_size = s.sizes[rows, h]
        new_busy = jnp.where(mask, s.busy + head_size, s.busy)
        rid = jnp.where(mask, s.slot_rid[rows, h], R)   # R => dropped

        def clear(a, v):
            return a.at[rows, h].set(jnp.where(mask, v, a[rows, h]))

        return s._replace(
            # -BIG keeps the retired prefix below every live value, so the
            # row stays globally sorted and counts/prefix sums stay valid
            starts=clear(s.starts, -jq.BIG),
            ends=clear(s.ends, -jq.BIG),
            sizes=clear(s.sizes, 0.0),
            head=s.head + mask,
            nq=s.nq - mask,
            busy=new_busy,
            load=s.load - jnp.where(mask, head_size, 0.0),
            completion=s.completion.at[rid].set(new_busy, mode="drop"),
        )

    return jax.lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# routing policies: pure selects over (load, adjacency, rng, trace row).
# The whole candidate chain is speculative — routing never reads ledger
# state (except batched_feasible's request-start mask, which is frozen for
# the chain since nothing mutates before an admission) — so it runs before
# the single fused feasibility pass.
# ---------------------------------------------------------------------------
def _route_next(policy: str, topo: TopologyArrays, load, cur, key, hop: int,
                tgt_row, feas_all, rr):
    """Forwarding target of ``cur``; returns (next_node, advanced_rr).

    Consulted speculatively for every hop — callers resolve which hops
    really happened afterwards (the rr pointer by realized forward count).
    """
    deg = topo.degree[cur]
    K = topo.adj.shape[0]
    if policy == "trace":
        return jnp.maximum(tgt_row[hop], 0), rr
    if policy == "round_robin":
        # stable-id pointer: probe rr, rr+1, ... (mod K), skip non-neighbors;
        # the pointer advances past the chosen probe (host Router semantics)
        offs = jnp.arange(K)
        cands = (rr + offs) % K
        off = jnp.argmax(topo.adj[cur][cands])
        return cands[off], (rr + off + 1) % K
    if policy == "least_loaded":
        # deterministic variant: ties break to the lowest node id (the host
        # router flips a coin; documented in DESIGN.md §5)
        return jnp.argmin(jnp.where(topo.adj[cur], load, jnp.inf)), rr
    if policy == "batched_feasible":
        # least-loaded neighbor that can still admit (cross-node mask from
        # the fused feasibility kernel); least-loaded fallback when nobody
        # can — identical tie-breaking to the host router (lowest id)
        ok = topo.adj[cur] & feas_all
        best_ok = jnp.argmin(jnp.where(ok, load, jnp.inf))
        best_any = jnp.argmin(jnp.where(topo.adj[cur], load, jnp.inf))
        return jnp.where(jnp.any(ok), best_ok, best_any), rr
    kh = jax.random.fold_in(key, hop)
    if policy == "random":
        u = jax.random.uniform(kh)
        pick = jnp.minimum((u * deg).astype(jnp.int32),
                           jnp.maximum(deg - 1, 0))
        return topo.neighbors[cur, pick], rr
    if policy == "power_of_two":
        k1, k2 = jax.random.split(kh)
        i1 = jnp.minimum((jax.random.uniform(k1) * deg).astype(jnp.int32),
                         jnp.maximum(deg - 1, 0))
        i2 = jnp.minimum(
            (jax.random.uniform(k2) * (deg - 1)).astype(jnp.int32),
            jnp.maximum(deg - 2, 0))
        i2 = jnp.where(i2 >= i1, i2 + 1, i2)        # sample w/o replacement
        a = topo.neighbors[cur, i1]
        b = topo.neighbors[cur, jnp.minimum(i2, jnp.maximum(deg - 1, 0))]
        two = jnp.where(load[a] <= load[b], a, b)
        return jnp.where(deg <= 1, topo.neighbors[cur, 0], two), rr
    raise ValueError(f"unknown fleetsim policy {policy!r}; "
                     f"options: {sorted(POLICIES)}")


# ---------------------------------------------------------------------------
# the scan step: one request end-to-end (fast-forward, chain, apply)
# ---------------------------------------------------------------------------
def _step(state: FleetState, x, *, topo: TopologyArrays, key, policy: str,
          max_forwards: int, discard_on_exhaust: bool, capacity: int,
          depth: int, use_pallas: bool, R: int, use_network: bool,
          net: Optional[NetParams]) -> FleetState:
    i, t, p, drel, origin, tgt_row, payload = x
    d = t + drel
    W = depth
    state = _retire(state, t, R)
    ps = p / topo.speeds                                    # (K,) scaled
    cpu_free = jnp.maximum(t, state.busy)

    feas_all = win_all = hrel_all = None
    if policy == "batched_feasible":
        # whole-fleet window gather (one take): with zero network this
        # feeds the single fused mask below; with a network each hop
        # re-scores it at the referral's delayed arrival (link_cost)
        w0_all = jnp.clip(state.head, 0, capacity - W)
        cols = w0_all[:, None] + jnp.arange(W)[None, :]
        win_all = lambda a: jnp.take_along_axis(a, cols, axis=1)
        hrel_all = state.head - w0_all
    if policy == "batched_feasible" and not use_network:
        # this is the Pallas fleet-feasibility kernel's slot in the step
        if use_pallas:
            from repro.kernels import ops as kops
            feas_all, _ = kops.fleet_feasibility(
                win_all(state.starts), win_all(state.ends),
                win_all(state.sizes), state.nq, ps, d, cpu_free, hrel_all)
        else:
            feas_all, _, _, _ = kref.fleet_search_ref(
                win_all(state.starts), win_all(state.ends),
                win_all(state.sizes), state.nq, ps, d, cpu_free, hrel_all)

    # speculative candidate chain: v[h] is where the request would sit
    # after h forwards (arriving at t_chain[h] once wire costs are paid);
    # the rr pointer is resolved by the realized count
    kreq = jax.random.fold_in(key, i)
    vs, rrs = [origin], [state.rr]
    cur, rr = origin, state.rr
    t_cur = t
    ts = [t_cur]
    for hop in range(max_forwards):
        feas_h = feas_all
        if policy == "batched_feasible" and use_network:
            # per-hop mask: each candidate scored at its delayed arrival
            # t_cur + delay(cur, cand) — the fused link_cost kernel's slot
            if use_pallas:
                from repro.kernels import ops as kops
                feas_h, _, _ = kops.link_cost(
                    win_all(state.starts), win_all(state.ends),
                    win_all(state.sizes), state.nq, ps, d, state.busy,
                    hrel_all, t_cur, net.latency[cur], net.inv_bw[cur],
                    payload)
            else:
                feas_h, _, _ = kref.link_cost_ref(
                    win_all(state.starts), win_all(state.ends),
                    win_all(state.sizes), state.nq, ps, d, state.busy,
                    hrel_all, t_cur, net.latency[cur], net.inv_bw[cur],
                    payload)
        nxt, rr = _route_next(policy, topo, state.load, cur, kreq, hop,
                              tgt_row, feas_h, rr)
        if use_network:
            # the hop's wire cost — latency plus frame serialization
            # (DESIGN.md §6) — as two scalar gathers, not a (K, K)
            # elementwise product per scan step
            t_cur = t_cur + net.latency[cur, nxt] + payload * net.inv_bw[cur, nxt]
        ts.append(t_cur)
        cur = nxt
        vs.append(cur)
        rrs.append(rr)
    v = jnp.stack(vs)                                       # (H,)
    rr_stack = jnp.stack(rrs)

    # gather each candidate's live window [w0, w0 + W) — all math below is
    # depth-wide, not buffer-wide (the retired prefix beyond the window is
    # dead weight the scan never has to touch again)
    w0 = jnp.clip(state.head[v], 0, capacity - W)
    head_rel = state.head[v] - w0

    def win(buf, h):
        return jax.lax.dynamic_slice(buf[v[h]], (w0[h],), (W,))

    H = max_forwards + 1
    starts_w = jnp.stack([win(state.starts, h) for h in range(H)])
    ends_w = jnp.stack([win(state.ends, h) for h in range(H)])
    sizes_w = jnp.stack([win(state.sizes, h) for h in range(H)])

    # the chain's per-candidate CPU-free floor: with a network the request
    # only reaches candidate h at t_chain[h], so the wire time comes
    # straight out of the admission slack — a referral can cause a miss
    if use_network:
        t_chain = jnp.stack(ts)                             # (H,)
        cpu_free_v = jnp.maximum(t_chain, state.busy[v])
    else:
        cpu_free_v = cpu_free[v]

    # one fused feasibility + geometry pass over the candidates' windows
    # (the window-full check doubles as the buffer-room check: w0 clamps to
    # capacity - W, so tail_rel == W <=> head + nq == capacity)
    ok, j, cap, _ = kref.fleet_search_ref(
        starts_w, ends_w, sizes_w, state.nq[v], ps[v], d, cpu_free_v,
        head_rel)

    # stop position: first candidate that admits or exhausts the chain
    # (degree 0 exhausts early; the M-th hop always stops)
    exh = (topo.degree[v] == 0).at[max_forwards].set(True)
    h_star = jnp.argmax(ok | exh)
    feas_at = ok[h_star]
    dst = v[h_star]
    w0_d = w0[h_star]
    nfwd = h_star
    discarded = ~feas_at & discard_on_exhaust
    forced_req = ~feas_at & (not discard_on_exhaust)
    state = state._replace(rr=rr_stack[nfwd])

    # apply at dst, within its window (jax_queue.insert_at — the shared
    # closed-form cascade — with the pre-computed search results)
    room = head_rel[h_star] + state.nq[dst] < W
    forced_ok = forced_req & room
    ovf = forced_req & ~room
    # a consulted candidate whose live window is exhausted can diverge from
    # the host's unbounded queue even on the feasible path (its admission
    # test reports "no room" where the host might admit) — surface it
    sat = jnp.any((head_rel + state.nq[v] >= W)
                  & (jnp.arange(max_forwards + 1) <= h_star))
    t_dst = t_chain[h_star] if use_network else t
    idle = state.busy[dst] < t_dst
    sr_w = jax.lax.dynamic_slice(state.slot_rid[dst], (w0_d,), (W,))
    n_starts, n_ends, n_sizes, admitted, (n_sr,) = jq.insert_at(
        starts_w[h_star], ends_w[h_star], sizes_w[h_star],
        head_rel[h_star], state.nq[dst], feas_at, forced_ok,
        j[h_star], cap[h_star], ps[dst], cpu_free_v[h_star],
        meta=(sr_w,), meta_vals=(i,))

    # idle CPU: the host engine pushes then immediately pops — net effect is
    # the request starts at its (wire-delayed) arrival and never enters
    # the ledger
    start_now = admitted & idle
    queue_it = admitted & ~idle
    c_now = t_dst + ps[dst]

    def put(buf, new, old):
        return jax.lax.dynamic_update_slice(
            buf, jnp.where(queue_it, new, old)[None, :], (dst, w0_d))

    state = state._replace(
        starts=put(state.starts, n_starts, starts_w[h_star]),
        ends=put(state.ends, n_ends, ends_w[h_star]),
        sizes=put(state.sizes, n_sizes, sizes_w[h_star]),
        slot_rid=put(state.slot_rid, n_sr, sr_w),
        nq=state.nq.at[dst].add(queue_it.astype(jnp.int32)),
        load=state.load.at[dst].add(jnp.where(queue_it, ps[dst], 0.0)),
        busy=state.busy.at[dst].set(
            jnp.where(start_now, c_now, state.busy[dst])),
    )
    # everything keyed by the *current* request rides the scan's stacked
    # outputs — only pop-time completions need the (R,) carry
    y = (jnp.where(admitted, dst, -1), discarded, ovf, start_now,
         jnp.where(start_now, c_now, 0.0), nfwd, sat)
    return state, y


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("policy", "max_forwards", "discard_on_exhaust",
                              "capacity", "depth", "use_pallas",
                              "use_network"))
def _simulate(reqs: RequestArrays, topo: TopologyArrays, params: SimParams,
              targets: jnp.ndarray, net: Optional[NetParams] = None, *,
              policy: str, max_forwards: int, discard_on_exhaust: bool,
              capacity: int, depth: int, use_pallas: bool,
              use_network: bool = False) -> FleetMetrics:
    R = reqs.arrival.shape[0]
    K = topo.speeds.shape[0]
    N = capacity
    dt = reqs.arrival.dtype
    state = FleetState(
        starts=jnp.full((K, N), jq.BIG, dt),
        ends=jnp.full((K, N), jq.BIG, dt),
        sizes=jnp.zeros((K, N), dt),
        slot_rid=jnp.zeros((K, N), jnp.int32),
        head=jnp.zeros((K,), jnp.int32),
        nq=jnp.zeros((K,), jnp.int32),
        busy=jnp.zeros((K,), dt),
        load=jnp.zeros((K,), dt),
        rr=jnp.zeros((), jnp.int32),
        completion=jnp.zeros((R,), dt),
    )
    key = jax.random.PRNGKey(params.seed)
    step = functools.partial(
        _step, topo=topo, key=key, policy=policy, max_forwards=max_forwards,
        discard_on_exhaust=discard_on_exhaust, capacity=capacity,
        depth=depth, use_pallas=use_pallas, R=R, use_network=use_network,
        net=net)
    d_abs = reqs.arrival + reqs.rel_deadline * params.sla_scale
    payload = (reqs.payload if reqs.payload is not None
               else jnp.zeros_like(reqs.arrival))
    xs = (jnp.arange(R, dtype=jnp.int32), reqs.arrival, reqs.proc,
          reqs.rel_deadline * params.sla_scale, reqs.origin, targets,
          payload)
    state, ys = jax.lax.scan(step, state, xs)
    state = _retire(state, jnp.asarray(jnp.inf, dt), R)     # drain

    served_by, disc, ovf, start_now, c_now, nfwd, sat = ys
    completion = jnp.where(start_now, c_now, state.completion)
    has_c = completion > 0
    met = has_c & (completion <= d_abs + _MET_EPS)
    outcome = jnp.where(
        disc, DISCARDED,
        jnp.where(ovf, OVERFLOW,
                  jnp.where(met, MET, jnp.where(has_c, LATE, PENDING))))
    n_proc = jnp.sum(has_c)
    resp = jnp.sum(jnp.where(has_c, completion - reqs.arrival, 0.0))
    last_arrival = jnp.max(reqs.arrival, initial=0.0)
    end_time = jnp.maximum(jnp.max(completion, initial=0.0), last_arrival)
    return FleetMetrics(
        total=jnp.int32(R),
        processed=n_proc.astype(jnp.int32),
        met_deadline=jnp.sum(met).astype(jnp.int32),
        forwards=jnp.sum(nfwd).astype(jnp.int32),
        discarded=jnp.sum(disc).astype(jnp.int32),
        overflow=jnp.sum(ovf).astype(jnp.int32),
        window_saturation=jnp.sum(sat).astype(jnp.int32),
        mean_response_time=resp / jnp.maximum(1, n_proc),
        end_time=end_time,
        outcome=outcome,
        completion=completion,
        served_by=served_by,
        forwards_used=nfwd,
    )


def simulate(reqs: RequestArrays, topo: TopologyArrays,
             params: Optional[SimParams] = None, *, policy: str = "random",
             max_forwards: int = 2, discard_on_exhaust: bool = False,
             capacity: int = 256, depth: Optional[int] = None,
             targets: Optional[jnp.ndarray] = None,
             use_pallas: bool = False,
             net: Optional[NetParams] = None) -> FleetMetrics:
    """Run the full fleet simulation as one device call.

    ``reqs``/``topo`` come from :mod:`repro.fleetsim.arrays` (or
    ``Workload.to_arrays()``); ``params`` carries the traced sweep axes.
    For (seeds x thresholds) sweeps, vmap :func:`simulate_fn` — every array
    argument takes a leading batch dimension, nothing leaves the device
    between sweep points.  ``capacity`` is the per-node slot-buffer width;
    each block occupies one slot for the whole run (head-pointer rows), so
    size it at the node's total admission count, not its peak depth.
    ``depth`` (default ``capacity``) is the live-window width the per-step
    math runs over — size it at peak queue depth + slack; smaller depth =
    faster steps.  Undersizing is never silent: a forced push that finds
    no free slot is reported in ``metrics.overflow``, and any request that
    merely *consulted* a node with an exhausted window (where the
    admission verdict could differ from the host's unbounded queue) counts
    into ``metrics.window_saturation`` — size capacity/depth so both stay
    0.  ``targets`` replays recorded forwarding choices (policy="trace",
    shape (R, max_forwards)).

    ``net`` (a :class:`repro.netsim.NetParams`) prices every referral
    hop: the wire time ``latency[u, v] + payload · inv_bw[u, v]`` delays
    the request's arrival along the speculative forward chain, consuming
    admission slack (DESIGN.md §6).  ``net=None`` compiles the exact
    pre-netsim step — and ``NetParams.zero`` reproduces its outcomes
    bit-for-bit (equivalence-guarded).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown fleetsim policy {policy!r}; "
                         f"options: {sorted(POLICIES)}")
    params = params if params is not None else SimParams.make()
    reqs = RequestArrays(
        *(jnp.asarray(a) for a in reqs[:5]),
        payload=None if reqs.payload is None else jnp.asarray(reqs.payload))
    topo = TopologyArrays(*(jnp.asarray(a) for a in topo))
    if targets is None:
        targets = jnp.full((reqs.arrival.shape[0], max(max_forwards, 1)),
                           -1, jnp.int32)
    depth = capacity if depth is None else min(depth, capacity)
    use_network = net is not None
    if use_network:
        if reqs.payload is None:
            # never silently drop the serialization half of the wire cost
            raise ValueError(
                "net= requires RequestArrays.payload (use pack_requests / "
                "Workload.to_arrays, or pass payload=zeros explicitly for "
                "a latency-only network)")
        net = NetParams(*(jnp.asarray(a, jnp.float32) for a in net))
    return _simulate(reqs, topo, params, jnp.asarray(targets, jnp.int32),
                     net, policy=policy, max_forwards=max_forwards,
                     discard_on_exhaust=discard_on_exhaust,
                     capacity=capacity, depth=depth, use_pallas=use_pallas,
                     use_network=use_network)


def simulate_fn(*, policy: str = "random", max_forwards: int = 2,
                discard_on_exhaust: bool = False, capacity: int = 256,
                depth: Optional[int] = None, use_pallas: bool = False,
                network: bool = False):
    """The jitted simulator with statics bound — the thing to ``jax.vmap``.

    Signature of the returned function:
    ``(reqs: RequestArrays, topo: TopologyArrays, params: SimParams,
    targets: (R, max_forwards) i32) -> FleetMetrics``; map any subset of
    arguments, e.g.::

        run = fleetsim.simulate_fn(policy="least_loaded")
        sweep = jax.vmap(run, in_axes=(None, None, SimParams(0, None), None))
        metrics = sweep(reqs, topo, SimParams.make(jnp.arange(32), 1.0), tgt)

    With ``network=True`` the returned function takes a fifth argument —
    a :class:`repro.netsim.NetParams` — making the network itself a sweep
    axis::

        run = fleetsim.simulate_fn(policy="least_loaded", network=True)
        grid = jax.vmap(run, in_axes=(None, None, None, None, 0))
        metrics = grid(reqs, topo, params, tgt, stacked_net_params)
    """
    return functools.partial(
        _simulate, policy=policy, max_forwards=max_forwards,
        discard_on_exhaust=discard_on_exhaust, capacity=capacity,
        depth=capacity if depth is None else min(depth, capacity),
        use_pallas=use_pallas, use_network=network)
