"""Wired 5G-MEC network model: what a referral actually costs.

The paper's headline metric is *reducing the number of referrals*, yet a
referral that is free and instantaneous makes that metric vacuous —
forwarding between MEC nodes rides the campus transport network, and the
transfer consumes exactly the deadline slack the admission test is trying
to protect.  :class:`LinkModel` prices every hop:

* **per-edge latency** — propagation + switching delay of the link
  ``(u, v)``, in the paper's UT units;
* **per-edge bandwidth** — serialization cost: a request's payload (the
  camera frame) takes ``payload / bandwidth`` UT on the wire;
* **per-service payloads** — Table I's resolution classes map to frame
  sizes (``pixels × bytes_per_pixel``), so a 4K referral costs more wire
  time than an HD one — same shape as the paper's S1..S6 ladder;
* **uplink** — the camera → MEC ingress leg (radio/fronthaul), consumed
  by :class:`repro.netsim.radio.RadioModel` per cell site.

``transfer_delay(u, v, service) = latency[u, v] + payload / bandwidth[u,
v]``.  The zero model (``LinkModel.zero``) prices every hop at exactly
0.0, and both orchestration cores are equivalence-guarded to reproduce
their network-free outputs bit-for-bit under it (DESIGN.md §6).

:class:`NetParams` is the device view — ``(K, K)`` latency and inverse-
bandwidth tensors the fleet simulator's event-time scan prices each
referral hop with (the re-arrival event is deferred by exactly the wire
time, DESIGN.md §7).  It is a NamedTuple of plain arrays, so it stacks
with ``tree_map`` and joins :class:`repro.fleetsim.SimParams` as a
vmappable sweep axis (a latency × bandwidth grid is one device call).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.request import Service
from repro.orchestration.topology import Topology

#: frame-size model: 24-bit RGB, payloads in MB (1e6 bytes)
BYTES_PER_PIXEL = 3.0

MatrixLike = Union[float, Sequence[Sequence[float]], np.ndarray]


def default_payload(service: Service) -> float:
    """Payload of one request in MB: the service's frame at 24bpp."""
    return service.pixels * BYTES_PER_PIXEL / 1e6


class NetParams(NamedTuple):
    """Traced network axes for the fleet simulator (vmappable).

    ``latency[u, v]`` is the hop latency in UT; ``inv_bw[u, v]`` the wire
    cost in UT per MB (``0`` = infinite bandwidth).  Entries for
    non-edges are 0 and never consulted — routing is adjacency-masked on
    both engines, so the tensors stay free of infs/NaNs under vmap.
    """
    latency: np.ndarray            # (K, K) f32 UT
    inv_bw: np.ndarray             # (K, K) f32 UT per MB

    @classmethod
    def zero(cls, n_nodes: int) -> "NetParams":
        """The free network: every hop costs exactly 0.0 UT."""
        z = np.zeros((n_nodes, n_nodes), np.float32)
        return cls(latency=z, inv_bw=z.copy())

    @classmethod
    def uniform(cls, n_nodes: int, latency: float,
                inv_bw: float = 0.0) -> "NetParams":
        """Every hop priced identically (zero diagonal) — the quick way
        to build sweep grids without a LinkModel."""
        lat = np.full((n_nodes, n_nodes), latency, np.float32)
        ibw = np.full((n_nodes, n_nodes), inv_bw, np.float32)
        np.fill_diagonal(lat, 0.0)
        np.fill_diagonal(ibw, 0.0)
        return cls(latency=lat, inv_bw=ibw)

    @property
    def n_nodes(self) -> int:
        return self.latency.shape[-1]


# ---------------------------------------------------------------------------
# link profiles: (latency UT, bandwidth MB/UT) per link class.  Calibrated to
# the paper's scales (proc 20-180 UT, deadlines 4000-9000 UT): a campus-LAN
# referral of an S1 frame (~24.9 MB) costs ~5 + 20 = 25 UT, a WAN backhaul
# hop an order of magnitude more.
# ---------------------------------------------------------------------------
PROFILES: Dict[str, Dict[str, float]] = {
    "zero": dict(latency=0.0, bandwidth=math.inf,
                 backhaul_latency=0.0, backhaul_bandwidth=math.inf,
                 uplink_latency=0.0, uplink_bandwidth=math.inf),
    # the paper's venue: MEC nodes on one campus aggregation network
    "campus": dict(latency=5.0, bandwidth=1.25,
                   backhaul_latency=30.0, backhaul_bandwidth=0.3125,
                   uplink_latency=2.0, uplink_bandwidth=0.625),
    # metro-area MEC federation
    "metro": dict(latency=15.0, bandwidth=0.5,
                  backhaul_latency=60.0, backhaul_bandwidth=0.125,
                  uplink_latency=4.0, uplink_bandwidth=0.5),
    # wide-area offload (every referral is expensive)
    "wan": dict(latency=80.0, bandwidth=0.125,
                backhaul_latency=160.0, backhaul_bandwidth=0.0625,
                uplink_latency=8.0, uplink_bandwidth=0.25),
}


def _as_matrix(value: MatrixLike, n: int, name: str) -> np.ndarray:
    if np.isscalar(value):
        m = np.full((n, n), float(value), np.float64)
    else:
        m = np.asarray(value, np.float64)
        if m.shape != (n, n):
            raise ValueError(f"{name} must be scalar or ({n}, {n}), "
                             f"got shape {m.shape}")
    return m


class LinkModel:
    """Per-edge latency + bandwidth over a :class:`Topology`, with a
    per-service payload model.

    ``latency``/``bandwidth`` are scalars (uniform links) or full
    ``(n, n)`` matrices; only entries on topology edges are meaningful
    (querying a non-edge raises — neither engine ever forwards across
    one).  ``payloads`` overrides the frame-size model per service name;
    anything absent falls back to ``pixels × bytes_per_pixel``.
    ``uplink_latency``/``uplink_bandwidth`` price the camera → MEC
    ingress leg (:class:`~repro.netsim.radio.RadioModel` cells default to
    them).
    """

    def __init__(self, topology: Topology,
                 latency: MatrixLike = 0.0,
                 bandwidth: MatrixLike = math.inf, *,
                 payloads: Optional[Dict[str, float]] = None,
                 bytes_per_pixel: float = BYTES_PER_PIXEL,
                 uplink_latency: float = 0.0,
                 uplink_bandwidth: float = math.inf,
                 name: str = "custom"):
        n = topology.n_nodes
        self.topology = topology
        self.name = name
        self.payloads = dict(payloads or {})
        self.bytes_per_pixel = float(bytes_per_pixel)
        self.uplink_latency = float(uplink_latency)
        self.uplink_bandwidth = float(uplink_bandwidth)

        lat = _as_matrix(latency, n, "latency")
        bw = _as_matrix(bandwidth, n, "bandwidth")
        if (lat < 0).any():
            raise ValueError("link latency must be non-negative")
        if (bw <= 0).any():
            raise ValueError("link bandwidth must be positive")
        edge = np.zeros((n, n), bool)
        for u, v in topology.edges():
            edge[u, v] = edge[v, u] = True
        # non-edges and the diagonal are priced 0 and guarded at query
        # time: neither engine forwards across them, and zeros keep the
        # device tensors inf/NaN-free under vmap
        self._edge = edge
        self._lat = np.where(edge, lat, 0.0)
        with np.errstate(divide="ignore"):
            self._inv_bw = np.where(edge, np.where(np.isinf(bw), 0.0,
                                                   1.0 / bw), 0.0)

    # -- queries -------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self.topology.n_nodes

    def payload_of(self, service: Service) -> float:
        """Request payload in MB (override table, else the frame model)."""
        got = self.payloads.get(service.name)
        if got is not None:
            return float(got)
        return service.pixels * self.bytes_per_pixel / 1e6

    def transfer_delay(self, src: int, dst: int, service: Service) -> float:
        """Wire cost of referring ``service`` over the edge ``src→dst``."""
        if src == dst:
            return 0.0
        if not self._edge[src, dst]:
            raise ValueError(f"({src}, {dst}) is not an edge of "
                             f"{self.topology.name!r}; referrals only "
                             "traverse topology links")
        return float(self._lat[src, dst]
                     + self.payload_of(service) * self._inv_bw[src, dst])

    def uplink_delay(self, service: Service) -> float:
        """Camera → MEC ingress cost with the model's default uplink."""
        if math.isinf(self.uplink_bandwidth):
            return self.uplink_latency
        return self.uplink_latency + self.payload_of(service) / self.uplink_bandwidth

    @property
    def is_zero(self) -> bool:
        """True iff every hop (and the uplink) costs exactly 0.0."""
        return (not self._lat.any() and not self._inv_bw.any()
                and self.uplink_latency == 0.0
                and math.isinf(self.uplink_bandwidth))

    def net_params(self, dtype=np.float32) -> NetParams:
        """Device view: the (K, K) tensors the fleet simulator scans."""
        return NetParams(latency=self._lat.astype(dtype),
                         inv_bw=self._inv_bw.astype(dtype))

    def __repr__(self) -> str:
        return (f"LinkModel({self.name!r}, n={self.n_nodes}, "
                f"{'zero' if self.is_zero else 'priced'})")

    # -- constructors --------------------------------------------------------
    @classmethod
    def zero(cls, topology: Topology) -> "LinkModel":
        """The free network — equivalence-guarded against no network."""
        return cls(topology, 0.0, math.inf, name="zero")

    @classmethod
    def uniform(cls, topology: Topology, latency: float, bandwidth: float,
                name: str = "uniform", **kw) -> "LinkModel":
        return cls(topology, latency, bandwidth, name=name, **kw)

    @classmethod
    def preset(cls, topology: Topology, profile: str = "campus",
               cloud_nodes: Sequence[int] = ()) -> "LinkModel":
        """A named link profile over ``topology``.

        ``cloud_nodes`` marks the backhaul tier: any edge touching one of
        them is priced with the profile's ``backhaul_*`` numbers (use it
        with :meth:`Topology.two_tier` — the cloud ids are
        ``range(n_edge, n_edge + n_cloud)``).
        """
        if profile not in PROFILES:
            raise ValueError(f"unknown link profile {profile!r}; "
                             f"options: {sorted(PROFILES)}")
        p = PROFILES[profile]
        n = topology.n_nodes
        lat = np.full((n, n), p["latency"], np.float64)
        bw = np.full((n, n), p["bandwidth"], np.float64)
        for c in cloud_nodes:
            lat[c, :] = lat[:, c] = p["backhaul_latency"]
            bw[c, :] = bw[:, c] = p["backhaul_bandwidth"]
        return cls(topology, lat, bw,
                   uplink_latency=p["uplink_latency"],
                   uplink_bandwidth=p["uplink_bandwidth"],
                   name=profile)

    @classmethod
    def campus(cls, topology: Topology,
               cloud_nodes: Sequence[int] = ()) -> "LinkModel":
        """The paper's venue: one campus aggregation network."""
        return cls.preset(topology, "campus", cloud_nodes)


def paper_campus(n_nodes: int = 3) -> Tuple[Topology, "LinkModel"]:
    """The paper's 5G campus: ``n_nodes`` MEC nodes on a full mesh with
    campus-LAN link pricing.  Returns ``(topology, link_model)``."""
    topo = Topology.full_mesh(n_nodes)
    return topo, LinkModel.campus(topo)
