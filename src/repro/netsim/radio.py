"""Radio access model: which MEC node a request enters at, and what the
uplink costs.

The paper fixes each camera to one MEC node; a 5G campus does not — UEs
(cameras, phones, AGVs) attach to *cell sites*, each cell fronts one MEC
node, and mobility hands a UE over between cells mid-experiment.  The
radio model supplies the two quantities the orchestration plane needs:

* **ingress node** — ``cell_of(ue, t).node``: where the request enters
  the MEC fleet (a handover changes it);
* **uplink delay** — radio + fronthaul latency plus the frame's wire
  time on the cell's uplink; it shifts the request's arrival *at the
  node* while the SLA clock starts at capture time, so the uplink
  consumes deadline budget exactly like a referral does.

:class:`RadioWorkload` packages both as a :class:`~repro.orchestration.
workload.Workload` axis: it wraps any base workload, reinterprets the
base origins as UE ids, and emits requests whose origin / arrival /
deadline-budget have been pushed through the radio model.  A zero radio
(0-latency, infinite-bandwidth cells, identity attachment) reproduces
the base workload exactly — the same equivalence contract the link model
honors (DESIGN.md §6).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request, Service
from repro.netsim.link import LinkModel, default_payload
from repro.orchestration.topology import Topology
from repro.orchestration.workload import Workload

#: deadline floor after uplink cost: a request whose uplink eats the whole
#: SLA budget still needs a positive deadline to exist; it will simply
#: (correctly) be infeasible everywhere and run forced/late.
MIN_DEADLINE = 1e-6


@dataclasses.dataclass(frozen=True)
class CellSite:
    """One gNB/cell: fronts one MEC node, owns its uplink pricing."""
    cell_id: int
    node: int                          # MEC node this cell's traffic enters
    uplink_latency: float = 0.0        # radio + fronthaul, UT
    uplink_bandwidth: float = math.inf  # MB/UT

    def uplink_delay(self, payload_mb: float) -> float:
        if math.isinf(self.uplink_bandwidth):
            return self.uplink_latency
        return self.uplink_latency + payload_mb / self.uplink_bandwidth


class RadioModel:
    """Cell/UE attachment with an optional handover (mobility) trace.

    ``attachment[ue]`` is the UE's initial cell (default ``ue %
    n_cells``); ``mobility[ue]`` is a time-sorted ``[(t, cell_id), ...]``
    handover schedule — at time ``t`` the UE detaches from its previous
    cell and all its subsequent traffic enters the new cell's node.
    Queries are pure functions of ``(ue, t)`` so workload generation
    stays deterministic.
    """

    def __init__(self, cells: Sequence[CellSite],
                 attachment: Optional[Dict[int, int]] = None,
                 mobility: Optional[Dict[int, Sequence[Tuple[float, int]]]]
                 = None,
                 name: str = "radio"):
        if not cells:
            raise ValueError("need at least one cell site")
        self.cells = {c.cell_id: c for c in cells}
        if len(self.cells) != len(cells):
            raise ValueError("duplicate cell_id")
        self.name = name
        self._cell_order = sorted(self.cells)
        self.attachment = dict(attachment or {})
        self.mobility: Dict[int, List[Tuple[float, int]]] = {}
        for ue, events in (mobility or {}).items():
            ev = sorted((float(t), int(c)) for t, c in events)
            for _, c in ev:
                if c not in self.cells:
                    raise ValueError(f"handover target cell {c} unknown")
            self.mobility[ue] = ev

    @property
    def n_nodes(self) -> int:
        return 1 + max(c.node for c in self.cells.values())

    def initial_cell(self, ue: int) -> int:
        got = self.attachment.get(ue)
        if got is not None:
            return got
        return self._cell_order[ue % len(self._cell_order)]

    def cell_of(self, ue: int, t: float) -> CellSite:
        """The cell ``ue`` is attached to at time ``t``."""
        cell = self.initial_cell(ue)
        events = self.mobility.get(ue)
        if events:
            # last handover at or before t wins
            k = bisect.bisect_right([e[0] for e in events], t)
            if k:
                cell = events[k - 1][1]
        return self.cells[cell]

    def ingress(self, ue: int, t: float) -> int:
        """MEC node a request from ``ue`` at time ``t`` enters."""
        return self.cell_of(ue, t).node

    def handovers(self, ue: int) -> int:
        return len(self.mobility.get(ue, ()))

    # -- constructors --------------------------------------------------------
    @classmethod
    def per_node(cls, topology: Topology, cells_per_node: int = 1, *,
                 uplink_latency: float = 0.0,
                 uplink_bandwidth: float = math.inf,
                 name: str = "per_node") -> "RadioModel":
        """``cells_per_node`` identical cells fronting every MEC node
        (cell ids are ``node * cells_per_node + k``)."""
        cells = [CellSite(n * cells_per_node + k, n,
                          uplink_latency, uplink_bandwidth)
                 for n in range(topology.n_nodes)
                 for k in range(cells_per_node)]
        return cls(cells, name=name)

    @classmethod
    def from_link(cls, link: LinkModel, cells_per_node: int = 1,
                  name: Optional[str] = None) -> "RadioModel":
        """Cells priced with the link model's uplink profile."""
        return cls.per_node(link.topology, cells_per_node,
                            uplink_latency=link.uplink_latency,
                            uplink_bandwidth=link.uplink_bandwidth,
                            name=name or f"radio:{link.name}")

    def with_random_mobility(self, n_ues: int, horizon: float,
                             handovers_per_ue: float = 1.0,
                             seed: int = 0) -> "RadioModel":
        """A copy with a seeded random-handover trace: each UE performs
        ``Poisson(handovers_per_ue)`` handovers at uniform times to
        uniformly random other cells (deterministic given ``seed``)."""
        rng = random.Random(f"mobility:{self.name}:{seed}:{n_ues}")
        cells = list(self._cell_order)
        mobility: Dict[int, List[Tuple[float, int]]] = {}
        for ue in range(n_ues):
            # inverse-CDF Poisson draw keeps the stream process-stable
            n_ho = 0
            acc, p = math.exp(-handovers_per_ue), math.exp(-handovers_per_ue)
            u = rng.random()
            while u > acc and n_ho < 64:
                n_ho += 1
                p *= handovers_per_ue / n_ho
                acc += p
            if not n_ho:
                continue
            cur = self.initial_cell(ue)
            events = []
            for t in sorted(rng.uniform(0.0, horizon) for _ in range(n_ho)):
                others = [c for c in cells if c != cur] or [cur]
                cur = rng.choice(others)
                events.append((t, cur))
            mobility[ue] = events
        return RadioModel(list(self.cells.values()),
                          attachment=dict(self.attachment),
                          mobility=mobility,
                          name=f"{self.name}+mob{seed}")


class RadioWorkload(Workload):
    """A base workload pushed through the radio model.

    The base workload's ``origin_node`` values are reinterpreted as **UE
    ids**; each request's MEC origin becomes its UE's cell's node *at
    capture time* (so handovers re-home traffic mid-run), its arrival is
    shifted by the cell's uplink delay, and its relative deadline shrinks
    by the same amount — the SLA clock starts at capture, not at node
    ingress.  ``link`` supplies the payload model (frame sizes); without
    one the uplink is pure latency.
    """

    def __init__(self, base: Workload, radio: RadioModel,
                 link: Optional[LinkModel] = None,
                 name: Optional[str] = None):
        self.base = base
        self.radio = radio
        self.link = link
        self.name = name or f"{base.name}@{radio.name}"
        self.n_nodes = radio.n_nodes
        self._svc_cache: Dict[Tuple[str, float, float], Service] = {}

    def _payload(self, service: Service) -> float:
        if self.link is not None:
            return self.link.payload_of(service)
        return default_payload(service)

    def _budgeted(self, service: Service, d_up: float) -> Service:
        if d_up == 0.0:
            return service
        budget = max(service.deadline - d_up, MIN_DEADLINE)
        key = (service.name, service.proc_time, budget)
        svc = self._svc_cache.get(key)
        if svc is None:
            svc = dataclasses.replace(service, deadline=budget)
            self._svc_cache[key] = svc
        return svc

    def generate(self, seed: int) -> List[Request]:
        requests: List[Request] = []
        for r in self.base.generate(seed):
            ue, t_cap = r.origin_node, r.arrival_time
            cell = self.radio.cell_of(ue, t_cap)
            d_up = cell.uplink_delay(self._payload(r.service))
            requests.append(Request(
                service=self._budgeted(r.service, d_up),
                arrival_time=t_cap + d_up,
                origin_node=cell.node,
            ))
        return self._finish(requests)
