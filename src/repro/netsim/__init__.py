"""repro.netsim — the 5G-MEC network model that makes referrals cost
something.

Three pieces, composable with both orchestration cores:

* :class:`LinkModel` — per-edge latency + bandwidth over a
  :class:`~repro.orchestration.topology.Topology`, per-service payload
  sizes (Table I resolutions → frame MB), campus/metro/wan presets;
* :class:`RadioModel` / :class:`RadioWorkload` — cell/UE attachment,
  uplink pricing and mobility handovers as a workload axis;
* :class:`NetParams` — the ``(K, K)`` device tensors
  :func:`repro.fleetsim.simulate` folds into its forward-chain scoring
  (a vmappable sweep axis next to ``SimParams``).

Quick start::

    from repro.core.block_queue import FastPreferentialQueue
    from repro.netsim import LinkModel, paper_campus
    from repro.orchestration import Orchestrator, Router

    topo, link = paper_campus()          # 3 MEC nodes, campus-LAN pricing
    orch = Orchestrator(topo, FastPreferentialQueue, network=link)
    result = orch.run(requests)          # referrals now consume slack

The zero model (``LinkModel.zero(topo)``) reproduces the network-free
outputs of both engines exactly — the equivalence contract in DESIGN.md
§6, guarded by tests/test_netsim.py and ``repro.fleetsim.validate
--net zero``.
"""
from repro.netsim.link import (BYTES_PER_PIXEL, PROFILES, LinkModel,
                               NetParams, default_payload, paper_campus)
from repro.netsim.radio import CellSite, RadioModel, RadioWorkload

__all__ = [
    "BYTES_PER_PIXEL", "PROFILES", "LinkModel", "NetParams",
    "default_payload", "paper_campus",
    "CellSite", "RadioModel", "RadioWorkload",
]
