"""Sharding rules: map parameter/activation names onto the production mesh.

Mesh axes (launch/mesh.py): ``data`` (FSDP + batch), ``model`` (TP/EP), and
optionally ``pod`` (multi-pod data parallelism).  Rules return
``PartitionSpec`` trees aligned with each model's param tree; dims that do
not divide evenly fall back per-dim according to GSPMD's uneven-sharding
support (verified to compile) or to replication where a rule requests it.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# ---------------------------------------------------------------------------
# Logical axis rules.  Model code annotates tensors with *logical* axis names
# ("dp", "fsdp", "tp", "seq", "expert"); the launcher installs a mapping to
# physical mesh axes per (mesh, shape-cell).  With no rules installed (smoke
# tests on a single device) every hint is a no-op.
# ---------------------------------------------------------------------------
_RULES: dict = {}
_MESH: Optional["Mesh"] = None


def set_rules(mesh: Optional["Mesh"] = None, **mapping) -> None:
    """Install logical→physical axis rules (None values clear an axis)."""
    global _RULES, _MESH
    _RULES = {k: v for k, v in mapping.items() if v is not None}
    if mesh is not None:
        _MESH = mesh


def clear_rules() -> None:
    global _RULES, _MESH
    _RULES = {}
    _MESH = None


def get_rules() -> dict:
    return dict(_RULES)


def active_mesh() -> Optional["Mesh"]:
    return _MESH


def logical(*names: Optional[str]) -> P:
    """Build a PartitionSpec from logical axis names via installed rules."""
    return P(*[_RULES.get(n) if n else None for n in names])


def hint(x: jnp.ndarray, *names: Optional[str]) -> jnp.ndarray:
    """Logical with_sharding_constraint; no-op without rules or mesh."""
    if not _RULES:
        return x
    return shard_hint(x, logical(*names))


def shard_hint(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint that no-ops when no mesh is active.

    Model code calls this unconditionally; smoke tests (single CPU device,
    no mesh) skip the constraint, dry-runs under ``with mesh:`` apply it.
    """
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All mesh axes used for data parallelism (pod-major)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_parallel_size(mesh: Mesh) -> int:
    size = 1
    for a in data_axes(mesh):
        size *= mesh.shape[a]
    return size


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Widest divisible data-parallel sharding for a batch dimension.

    Prefers pod×data; falls back to data alone; replicates batch-1 latency
    shapes (the roofline table then reports those cells as model-parallel
    only — the honest answer for batch=1 on a 256-chip pod).
    """
    axes = data_axes(mesh)
    full = 1
    for a in axes:
        full *= mesh.shape[a]
    if axes and global_batch % full == 0:
        return P(axes)
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P()


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def divisible(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1
