"""Deadline-aware vision serving engine — the paper's orchestration plane
driving a real JAX data plane.

Mapping (DESIGN.md §3):

* MEC node          -> :class:`ServingReplica` (one model replica; on a pod,
                       one model-parallel group)
* request           -> an inference call with an SLA deadline; its service
                       class comes from the input resolution (Table I:
                       4K/FullHD/HD -> S1/S2/S3-style classes)
* node CPU timeline -> replica device-time ledger; proc_time comes from a
                       measured per-(service, batch) step-time model
* queue             -> FIFO (SFA baseline) or the preferential block queue
* forwarding        -> re-route to another replica (max M, then forced)

Beyond the paper: **deadline-aware batching** — the executor pops a *run*
of queue-head requests of the same service class (up to ``max_batch``) and
executes them as one device batch; the ledger treats the run like one block
per request, so admission guarantees survive (batching only ever finishes
requests earlier than their scheduled ends, never later, because batched
throughput >= sequential throughput for the same work — enforced by using
the measured batched step time as the per-request proc_time upper bound).
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.block_queue import FastPreferentialQueue
from repro.core.node import QueueLike
from repro.core.queues import FIFOQueue
from repro.core.request import Request, Service
from repro.orchestration.orchestrator import place
from repro.orchestration.router import Router
from repro.orchestration.topology import Topology


@dataclasses.dataclass
class ServiceClass:
    """One resolution class backed by a measured step-time model."""
    name: str
    resolution: int
    deadline: float                   # relative SLA deadline (engine time)
    proc_time: float                  # worst-case per-request time
    batch_proc_time: Dict[int, float] = dataclasses.field(default_factory=dict)

    def service(self) -> Service:
        return Service(self.name, pixels=self.resolution ** 2,
                       environment="serving", proc_time=self.proc_time,
                       deadline=self.deadline)


@dataclasses.dataclass
class ServeRequest:
    payload: Any                       # e.g. image array
    cls: ServiceClass
    arrival: float
    rid: int
    forwards: int = 0
    done_at: Optional[float] = None
    result: Any = None

    @property
    def deadline(self) -> float:
        return self.arrival + self.cls.deadline

    @property
    def proc_time(self) -> float:
        """Worst-case per-request time (router feasibility scoring reads it)."""
        return self.cls.proc_time


class ServingReplica:
    """One model replica with a deadline-aware admission queue.

    ``speed`` is the replica's :class:`~repro.orchestration.topology.
    Topology` speed factor: a ``speed = s`` replica admits *and executes*
    every request ``s``-times faster — the same scaling contract as the
    simulation plane's :class:`~repro.orchestration.orchestrator.
    Orchestrator`, so the router's speed-scaled feasibility scoring
    (``Router._batched_feasible``) and the data plane agree.
    :class:`DeadlineAwareEngine` overwrites it from an explicitly
    provided topology (then the source of truth for per-node speeds);
    with the defaulted full mesh the replica's own ``speed`` stands.
    """

    def __init__(self, replica_id: int, run_batch: Callable[[str, List[Any]], Any],
                 queue: Optional[QueueLike] = None, max_batch: int = 8,
                 speed: float = 1.0):
        if speed <= 0:
            raise ValueError(f"speed must be positive, got {speed}")
        self.replica_id = replica_id
        self.run_batch = run_batch
        self.queue = queue if queue is not None else FastPreferentialQueue()
        self.max_batch = max_batch
        self.speed = float(speed)
        self.busy_until = 0.0
        self._by_rid: Dict[int, ServeRequest] = {}
        self._scaled_services: Dict[tuple, Service] = {}
        self.stats = {"admitted": 0, "rejected": 0, "forced": 0,
                      "met": 0, "missed": 0, "batches": 0}

    def cpu_free_time(self, now: float) -> float:
        return max(now, self.busy_until)

    def _scaled_service(self, cls: ServiceClass) -> Service:
        """The request's admission-ledger service, proc scaled by speed
        (deadline untouched — SLAs don't move with hardware)."""
        svc = cls.service()
        if self.speed == 1.0:
            return svc
        key = (svc.name, svc.proc_time, svc.deadline, self.speed)
        scaled = self._scaled_services.get(key)
        if scaled is None:
            scaled = dataclasses.replace(svc,
                                         proc_time=svc.proc_time / self.speed)
            self._scaled_services[key] = scaled
        return scaled

    def try_admit(self, req: ServeRequest, now: float, forced: bool) -> bool:
        core_req = Request(service=self._scaled_service(req.cls),
                           arrival_time=req.arrival,
                           origin_node=self.replica_id, rid=req.rid,
                           forwards=req.forwards)
        ok = self.queue.push(core_req, self.cpu_free_time(now), forced=forced)
        if ok:
            self._by_rid[req.rid] = req
            self.stats["admitted"] += 1
            if forced:
                self.stats["forced"] += 1
        else:
            self.stats["rejected"] += 1
        return ok

    def next_run_time(self) -> float:
        """Earliest time the next run could start (inf if queue empty)."""
        head = self.queue.peek() if hasattr(self.queue, "peek") else None
        if head is None:
            return float("inf") if len(self.queue) == 0 else self.busy_until
        return max(self.busy_until, head.arrival_time)

    def _pop_run(self, start: float) -> List[ServeRequest]:
        """Pop up to max_batch queue-head requests of one service class that
        have arrived by ``start``."""
        run: List[ServeRequest] = []
        head_cls = None
        while len(run) < self.max_batch:
            nxt = self.queue.peek() if hasattr(self.queue, "peek") else None
            if nxt is None and len(self.queue) == 0:
                break
            if nxt is not None:
                if nxt.arrival_time > start + 1e-9:
                    break
                cls_name = nxt.service.name
                if head_cls is not None and cls_name != head_cls:
                    break
                head_cls = cls_name
            popped = self.queue.pop()
            if popped is None:
                break
            run.append(self._by_rid.pop(popped.rid))
            if nxt is None:
                break                      # queue without peek: batch of 1
        return run

    def step(self, now: float) -> Tuple[float, List[ServeRequest]]:
        """Execute one batched run work-conservingly starting at ``now``
        (requires now >= next_run_time). Returns (t_done, requests)."""
        if now < self.busy_until or len(self.queue) == 0:
            return self.busy_until, []
        run = self._pop_run(now)
        if not run:
            return self.busy_until, []
        cls = run[0].cls
        b = len(run)
        # the measured step-time model is for a reference (speed-1) replica;
        # this replica executes speed× faster — matching the scaled ledger
        # blocks admission committed to, so admission guarantees survive
        t_batch = cls.batch_proc_time.get(b, cls.proc_time * b) / self.speed
        outs = self.run_batch(cls.name, [r.payload for r in run])
        self.stats["batches"] += 1
        done = now + t_batch
        self.busy_until = done
        for r, o in zip(run, outs):
            r.done_at = done
            r.result = o
            if done <= r.deadline + 1e-9:
                self.stats["met"] += 1
            else:
                self.stats["missed"] += 1
        return done, run


class DeadlineAwareEngine:
    """Multi-replica orchestrator: admission + sequential forwarding.

    Forwarding is NOT re-implemented here — target selection and the
    admit/forward/force loop come from the orchestration core
    (:class:`repro.orchestration.Router` + :func:`repro.orchestration.place`),
    so the engine honors any topology (e.g. ``Topology.two_tier`` with a
    fast cloud replica group) and any router policy, including
    ``batched_feasible`` device-side scoring.
    """

    def __init__(self, replicas: Sequence[ServingReplica], max_forwards: int = 2,
                 rng_seed: int = 0, topology: Optional[Topology] = None,
                 forward_policy: str = "random"):
        self.replicas = list(replicas)
        for idx, rep in enumerate(self.replicas):
            if rep.replica_id != idx:
                raise ValueError("replicas must be indexed by replica_id "
                                 f"(got id {rep.replica_id} at position {idx})")
        self.max_forwards = max_forwards
        explicit_topology = topology is not None
        self.topology = topology if explicit_topology \
            else Topology.full_mesh(len(self.replicas))
        if self.topology.n_nodes != len(self.replicas):
            raise ValueError(f"topology has {self.topology.n_nodes} nodes "
                             f"for {len(self.replicas)} replicas")
        # a provided topology is the source of truth for per-node speeds:
        # the data plane must execute at the same rate the router scores
        # and the admission ledger commits to (ROADMAP speed-scaling fix).
        # Without one, the replicas' own speeds stand — the defaulted
        # full mesh must not clobber an explicit ServingReplica(speed=...)
        if explicit_topology:
            for idx, rep in enumerate(self.replicas):
                rep.speed = self.topology.speed(idx)
        self.router = Router(self.topology, forward_policy,
                             rng=random.Random(f"serving-fwd:{rng_seed}"))
        self._rng = np.random.default_rng(rng_seed)
        self._next_rid = 0
        self.forwards = 0

    def submit(self, payload: Any, cls: ServiceClass, now: float,
               origin: Optional[int] = None) -> ServeRequest:
        self.advance(now)      # execute everything that starts before `now`
        req = ServeRequest(payload=payload, cls=cls, arrival=now,
                           rid=self._next_rid)
        self._next_rid += 1
        if origin is None:
            origin = int(self._rng.integers(len(self.replicas)))
        place(req, origin, self.replicas, self.router, now=now,
              max_forwards=self.max_forwards,
              admit=lambda rep, r, t, forced: rep.try_admit(r, t, forced=forced),
              on_forward=self._on_forward)
        return req

    def _on_forward(self, req: ServeRequest, src: ServingReplica,
                    dst: ServingReplica, now: float) -> None:
        self.forwards += 1

    def advance(self, now: float) -> None:
        """Event-driven execution: run every replica's pending runs whose
        start time is strictly before ``now`` (earliest-first for
        deterministic cross-replica ordering)."""
        while True:
            t_next, rep_next = min(
                ((r.next_run_time(), r) for r in self.replicas),
                key=lambda x: x[0])
            if t_next >= now or t_next == float("inf"):
                return
            rep_next.step(t_next)

    def drain(self, now: float) -> float:
        """Run every replica until all queues are empty. Returns end time."""
        self.advance(float("inf"))
        busy = [r.busy_until for r in self.replicas]
        return max([now] + busy)

    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {"forwards": self.forwards}
        for rep in self.replicas:
            for k, v in rep.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg


def measure_step_times(run_batch: Callable[[str, List[Any]], Any],
                       cls: ServiceClass, payload: Any,
                       batches=(1, 2, 4, 8), warmup: int = 1) -> None:
    """Fill cls.batch_proc_time with wall-clock measurements (and set
    proc_time to the measured batch-1 worst case)."""
    for b in batches:
        payloads = [payload] * b
        for _ in range(warmup):
            run_batch(cls.name, payloads)
        t0 = time.perf_counter()
        run_batch(cls.name, payloads)
        dt = time.perf_counter() - t0
        cls.batch_proc_time[b] = dt
    cls.proc_time = max(cls.proc_time, cls.batch_proc_time.get(1, 0.0))
