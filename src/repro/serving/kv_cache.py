"""KV-cache session pool for LM decode serving.

A fixed-capacity batched cache (the transformer's (L, B, S, KV, hd) layout)
is treated as B *slots*; sessions are assigned slots from a free list and
evicted on completion or deadline expiry.  This is the slot-allocation
layer; the cache tensors themselves live in repro.models.transformer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp


@dataclasses.dataclass
class Session:
    session_id: int
    slot: int
    length: int = 0
    deadline: float = float("inf")


class KVCachePool:
    def __init__(self, n_slots: int, max_len: int):
        self.n_slots = n_slots
        self.max_len = max_len
        self._free: List[int] = list(range(n_slots))
        self._sessions: Dict[int, Session] = {}
        self._next_id = 0

    def allocate(self, deadline: float = float("inf")) -> Optional[Session]:
        if not self._free:
            return None
        slot = self._free.pop()
        s = Session(self._next_id, slot, deadline=deadline)
        self._next_id += 1
        self._sessions[s.session_id] = s
        return s

    def release(self, session_id: int) -> None:
        s = self._sessions.pop(session_id, None)
        if s is not None:
            self._free.append(s.slot)

    def advance(self, session_id: int, n: int = 1) -> int:
        s = self._sessions[session_id]
        s.length += n
        if s.length > self.max_len:
            raise ValueError(f"session {session_id} exceeded max_len")
        return s.length

    def evict_expired(self, now: float) -> List[int]:
        dead = [sid for sid, s in self._sessions.items() if now > s.deadline]
        for sid in dead:
            self.release(sid)
        return dead

    @property
    def active(self) -> int:
        return len(self._sessions)

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_slots
