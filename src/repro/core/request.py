"""Request / service model for the MEC-LB orchestration plane.

Faithful to Table I of the paper: each *service* is a (resolution,
environment) pair with a worst-case processing time and a relative SLA
deadline, both in generic "UT" units.  A *request* is one invocation of a
service arriving at a MEC node.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

_req_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class Service:
    """One service class (Table I row)."""

    name: str
    pixels: int
    environment: str          # "busy" | "isolated"
    proc_time: float          # worst-case processing time (UT)
    deadline: float           # relative SLA deadline (UT)

    def __post_init__(self) -> None:
        if self.proc_time <= 0:
            raise ValueError(f"proc_time must be positive, got {self.proc_time}")
        if self.deadline <= 0:
            raise ValueError(f"deadline must be positive, got {self.deadline}")


@dataclasses.dataclass
class Request:
    """One inference request with an SLA deadline.

    ``deadline`` is *absolute*: ``arrival_time + service.deadline``.
    ``forwards`` counts how many times this request has been referred to a
    neighboring node (paper caps it at M=2).
    """

    service: Service
    arrival_time: float
    origin_node: int
    rid: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    forwards: int = 0

    # Filled in by the simulator / engine.
    completion_time: Optional[float] = None
    served_by: Optional[int] = None

    @property
    def proc_time(self) -> float:
        return self.service.proc_time

    @property
    def deadline(self) -> float:
        """Absolute deadline."""
        return self.arrival_time + self.service.deadline

    @property
    def met_deadline(self) -> bool:
        if self.completion_time is None:
            return False
        return self.completion_time <= self.deadline + 1e-9


# ---------------------------------------------------------------------------
# Table I of the paper.
# ---------------------------------------------------------------------------
SERVICES = {
    "S1": Service("S1", pixels=8_294_400, environment="busy", proc_time=180.0, deadline=9000.0),
    "S2": Service("S2", pixels=2_073_600, environment="busy", proc_time=44.0, deadline=9000.0),
    "S3": Service("S3", pixels=921_600, environment="busy", proc_time=20.0, deadline=9000.0),
    "S4": Service("S4", pixels=8_294_400, environment="isolated", proc_time=180.0, deadline=4000.0),
    "S5": Service("S5", pixels=2_073_600, environment="isolated", proc_time=44.0, deadline=4000.0),
    "S6": Service("S6", pixels=921_600, environment="isolated", proc_time=20.0, deadline=4000.0),
}

SERVICE_ORDER = ("S1", "S2", "S3", "S4", "S5", "S6")
