"""Deadline-aware preferential queue — Algorithms 1-5 of Boing et al. (2022).

The queue is a ledger of non-overlapping *time blocks* on the node's CPU
timeline.  Every admitted request ``R`` owns one block ``[start, end]`` with
``end - start == R.proc_time`` and ``end <= R.deadline`` (absolute).  Blocks
are kept *as late as feasible* (right-aligned at
``min(right_neighbor.start, deadline)``), which creates the free time-gaps of
the paper's Figs. 1-2 that later, tighter-deadline requests can slot into.

Semantics reconstructed from the paper (see DESIGN.md §2):

* ``push`` scans tail → head for the *rightmost feasible* insertion position.
  Position ``j`` (between blocks ``j-1`` and ``j``) is feasible iff

      min(starts[j], d_new) - (cpu_free + prefix_work[j]) >= p_new

  i.e. the window capped by the new deadline, after left-compacting every
  earlier block into its cumulative slack, still fits the new block.  This is
  exactly what the incremental ``_freeNeeded`` recursion of the paper's
  Alg. 2 computes (gap widths accumulated while recursing left).
* On success the new block is right-aligned in its window and earlier blocks
  are left-shifted only as much as needed (the Fig. 2c-d cascade: "the
  available spaces between R1-R2 and R2-R3 are reduced").  Left shifts never
  violate a deadline (ends only decrease) and never cross ``cpu_free`` (the
  feasibility test bounds the cascade by the compacted prefix).
* ``forced`` push (request exhausted its M forwards): the whole queue is
  compacted left ("all available time slots will be removed", Fig. 3) and the
  block is appended at the tail — late, but no *other* admitted deadline is
  disturbed.

The executor is work-conserving: ``pop`` hands out the head block immediately
when the CPU frees, so real completions only ever beat the ledger (the ledger
is a conservative admission-control commitment; invariant argued in
DESIGN.md §2).

Two interchangeable implementations:

* :class:`PreferentialQueue` — faithful tail→head linear scan, mirroring the
  paper's linked-list recursion (converted to iteration so queue depth is not
  bounded by the Python recursion limit).  O(n) per push.
* :class:`FastPreferentialQueue` — beyond-paper O(log n) feasibility search
  exploiting that cumulative slack ``S_j`` is monotone in ``j`` (derivation
  in DESIGN.md).  Observationally identical — property-tested against the
  faithful queue in ``tests/test_block_queue.py``.
"""
from __future__ import annotations

import bisect
from typing import List, Optional

from repro.core.request import Request

_EPS = 1e-9


class Block:
    """One scheduled request occupying ``[start, end]`` on the CPU timeline."""

    __slots__ = ("request", "start", "end")

    def __init__(self, request: Request, start: float, end: float):
        self.request = request
        self.start = start
        self.end = end

    @property
    def size(self) -> float:
        return self.end - self.start

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Block(r{self.request.rid}, [{self.start:.1f}, {self.end:.1f}], "
                f"d={self.request.deadline:.1f})")


class PreferentialQueue:
    """Paper-faithful preferential queue (Algorithms 1-5).

    ``forced_compaction`` selects between two readings of the paper's forced
    push ("R_new must be allocated at the end of the queue, and all available
    time slots will be removed"):

    * ``False`` (default) — the slots are removed *from consideration*: the
      forced block is appended plainly at the tail and the existing gap
      structure survives.  This reading reproduces the paper's Fig. 5/6
      results (preferential > FIFO on both metrics).
    * ``True`` — the literal Alg. 2 pseudo-code reading: the whole queue is
      physically compacted left before appending.  This destroys all gaps;
      under sustained overload (where forced pushes are frequent) it
      degenerates the preferential queue to FIFO behaviour and *cannot*
      reproduce the paper's reported gains.  Kept for the ablation in
      EXPERIMENTS.md §Paper-reproduction.
    """

    def __init__(self, forced_compaction: bool = False) -> None:
        self._blocks: List[Block] = []
        self._total_work = 0.0
        self.forced_compaction = forced_compaction

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._blocks)

    def is_empty(self) -> bool:
        return not self._blocks

    @property
    def blocks(self) -> List[Block]:
        return self._blocks

    def pending_work(self) -> float:
        return self._total_work

    def check_invariants(self, cpu_free_time: float = float("-inf")) -> None:
        """Raise AssertionError if the ledger is inconsistent (test hook)."""
        prev_end = cpu_free_time
        for b in self._blocks:
            assert b.start >= prev_end - 1e-6, f"overlap/out-of-order at {b}"
            assert abs(b.size - b.request.proc_time) < 1e-6, f"bad size at {b}"
            prev_end = b.end

    def scheduled_blocks(self, cpu_free_time: float = 0.0):
        """(start, end) per admitted block — the ledger the router's
        ``batched_feasible`` policy scores against (gaps included)."""
        return [(b.start, b.end) for b in self._blocks]

    def scheduled_late(self) -> int:
        """Number of blocks scheduled past their deadline (forced pushes only)."""
        return sum(1 for b in self._blocks if b.end > b.request.deadline + _EPS)

    def deadlines_respected(self) -> bool:
        """True iff every block's scheduled end is within its deadline."""
        return self.scheduled_late() == 0

    # -- Algorithm 1: push_request ------------------------------------------
    def push(self, request: Request, cpu_free_time: float, forced: bool = False) -> bool:
        p = request.proc_time
        d = request.deadline
        blocks = self._blocks
        n = len(blocks)

        # search_alloc_space (Alg. 2), iteratively, tail → head.  ``pw`` is
        # the prefix work left of the candidate position (the paper tracks the
        # complementary quantity ``_freeNeeded`` while recursing).
        placed = self._search_alloc_space(p, d, cpu_free_time)
        if placed is not None:
            j, window_right = placed
            self._insert_with_shift(j, request, window_right)
            self._total_work += p
            return True

        if not forced:
            return False

        # Forced push (Alg. 1 lines 11-18): append at the tail, ignoring the
        # gap structure.  Optionally compact first (see class docstring).
        if self.forced_compaction:
            self._compact_all(cpu_free_time)
        start = blocks[-1].end if blocks else cpu_free_time
        blocks.append(Block(request, start, start + p))
        self._total_work += p
        return True

    def _search_alloc_space(self, p: float, d: float, cpu_free_time: float):
        """Find the insertion slot: ``(position, window_right)`` or ``None``.

        The paper's Alg. 2 walks tail → head until it reaches the rightmost
        position whose useful area (Alg. 3) is non-empty — i.e. the window
        right edge ``min(right.start, d)`` exceeds the left neighbour's end.
        The new block is placed right-aligned there; any deficit must be
        covered by slack strictly to the left (cumulative-gap feasibility:
        ``cap - (cpu_free + prefix_work) >= p``).  Blocks to the *right* of
        the slot are never moved (Fig. 2d: R_new lands between R2 and R3,
        with residual gaps on both sides).  Feasibility at this position
        dominates all deeper positions (slack monotonicity, DESIGN.md §2),
        so a single test decides admission.
        """
        blocks = self._blocks
        n = len(blocks)
        pw = self._total_work            # prefix work of blocks[:j], j=n
        for j in range(n, -1, -1):
            left_end = blocks[j - 1].end if j > 0 else cpu_free_time
            right_start = blocks[j].start if j < n else float("inf")
            cap = min(right_start, d)    # get_useful_area (Alg. 3) right edge
            if cap > left_end:           # rightmost non-empty useful area
                if cap - (cpu_free_time + pw) >= p - _EPS:
                    return j, cap
                return None              # infeasible here => infeasible everywhere
            if j > 0:
                pw -= blocks[j - 1].size
        return None

    # -- Algorithms 4+5: shift_or_alloc / alloc_request ----------------------
    def _insert_with_shift(self, j: int, request: Request, window_right: float) -> None:
        p = request.proc_time
        new_start = window_right - p
        # Cascade left-shift (Fig. 2d): each earlier block is pulled left just
        # enough that it no longer overlaps the block to its right.
        required_end = new_start
        for i in range(j - 1, -1, -1):
            b = self._blocks[i]
            if b.end <= required_end + _EPS:
                break
            size = b.size
            b.end = required_end
            b.start = required_end - size
            required_end = b.start
        self._blocks.insert(j, Block(request, new_start, window_right))

    def _compact_all(self, cpu_free_time: float) -> None:
        t = cpu_free_time
        for b in self._blocks:
            size = b.size
            b.start = t
            b.end = t + size
            t = b.end

    # -- executor side -------------------------------------------------------
    def peek(self) -> Optional[Request]:
        return self._blocks[0].request if self._blocks else None

    def pop(self) -> Optional[Request]:
        if not self._blocks:
            return None
        blk = self._blocks.pop(0)
        self._total_work -= blk.size
        return blk.request


class FastPreferentialQueue(PreferentialQueue):
    """Sub-linear feasibility search (beyond-paper optimization).

    Uses the structure derived in DESIGN.md §2: the only position the paper's
    Alg. 2 can allocate at is the *rightmost non-empty useful area* ``j*``:

    * ``j* = e_hi = bisect(ends, d)`` when no admitted block straddles the
      deadline (window right edge = d), else
    * ``j*`` = the rightmost real gap left of the straddler,

    and feasibility at ``j*`` dominates every deeper position, so ONE test
    ``cap - (cpu_free + prefix_work[j*]) >= p`` decides admission.

    The index (``_starts``/``_ends``/``_sizes`` kept in lockstep with the
    block list) is maintained *incrementally* — C-speed ``list.insert`` /
    cascade writes — and the single prefix sum is computed from whichever
    end of the queue is closer, so a push costs
    ``O(log n + min(j*, n - j*) + cascade)`` instead of the faithful O(n)
    walk.  Accepted set and block layout are identical to the faithful queue
    (property-tested in tests/test_block_queue.py).
    """

    def __init__(self, forced_compaction: bool = False) -> None:
        super().__init__(forced_compaction)
        self._starts: List[float] = []
        self._ends: List[float] = []
        self._sizes: List[float] = []

    # -- index maintenance -----------------------------------------------
    def _prefix_work(self, j: int) -> float:
        sizes = self._sizes
        n = len(sizes)
        if j <= n - j:
            return sum(sizes[:j])
        return self._total_work - sum(sizes[j:])

    def _search_alloc_space(self, p: float, d: float, cpu_free_time: float):
        starts, ends = self._starts, self._ends
        n = len(starts)
        cap_idx = bisect.bisect_left(starts, d)   # first block starting >= d
        e_hi = bisect.bisect_left(ends, d)        # number of blocks ending < d

        if e_hi >= cap_idx:
            j, cap = e_hi, d
        else:
            # a block straddles d; rightmost real gap at/left of it
            j = -1
            for i in range(e_hi, 0, -1):
                if starts[i] > ends[i - 1]:
                    j = i
                    break
            if j < 0:
                cap0 = min(starts[0], d) if n else d
                if cap0 <= cpu_free_time:
                    return None
                j, cap = 0, cap0
            else:
                cap = min(starts[j], d)
        if cap - (cpu_free_time + self._prefix_work(j)) >= p - _EPS:
            return j, cap
        return None

    def _insert_with_shift(self, j: int, request: Request,
                           window_right: float) -> None:
        p = request.proc_time
        new_start = window_right - p
        required_end = new_start
        blocks = self._blocks
        starts, ends = self._starts, self._ends
        for i in range(j - 1, -1, -1):
            b = blocks[i]
            if b.end <= required_end + _EPS:
                break
            size = b.size
            b.end = required_end
            b.start = required_end - size
            ends[i] = b.end
            starts[i] = b.start
            required_end = b.start
        blocks.insert(j, Block(request, new_start, window_right))
        starts.insert(j, new_start)
        ends.insert(j, window_right)
        self._sizes.insert(j, p)

    def _compact_all(self, cpu_free_time: float) -> None:
        super()._compact_all(cpu_free_time)
        self._starts = [b.start for b in self._blocks]
        self._ends = [b.end for b in self._blocks]

    def push(self, request: Request, cpu_free_time: float,
             forced: bool = False) -> bool:
        ok = super().push(request, cpu_free_time, forced)
        if ok and len(self._starts) != len(self._blocks):
            # forced tail append path (base class bypasses _insert_with_shift)
            b = self._blocks[-1]
            self._starts.append(b.start)
            self._ends.append(b.end)
            self._sizes.append(b.size)
        return ok

    def pop(self) -> Optional[Request]:
        req = super().pop()
        if req is not None:
            self._starts.pop(0)
            self._ends.pop(0)
            self._sizes.pop(0)
        return req
