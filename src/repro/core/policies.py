"""Forwarding policies for the Sequential Forwarding Algorithm family.

The paper forwards to a *uniformly random* neighbor (excluding the current
node).  We additionally implement the related-work variants the paper
discusses, as comparison points:

* ``random``        — the paper / SFA [12]: uniform random neighbor.
* ``power_of_two``  — sample two random neighbors, forward to the one with
                      less pending work (classic Mitzenmacher po2; a natural
                      beyond-paper upgrade the paper's future-work hints at).
* ``least_loaded``  — consult all neighbors, pick the minimum pending work
                      (Beraldi et al. [11]-style, with full state).
* ``round_robin``   — deterministic cycling, a no-state baseline.

Policies only read ``pending_work()`` — they never touch queue internals, so
they compose with any queue discipline.

.. note:: This module is the legacy fully-connected API.  New code should
   use :class:`repro.orchestration.Router`, which implements the same
   strategies (plus ``batched_feasible``) over an arbitrary
   :class:`~repro.orchestration.topology.Topology`; the simulator routes
   through it since the unified-orchestration refactor.
"""
from __future__ import annotations

import random
from typing import List, Sequence

from repro.core.node import MECNode


def _candidates(nodes: Sequence[MECNode], exclude: int) -> List[MECNode]:
    return [n for n in nodes if n.node_id != exclude]


class ForwardPolicy:
    name = "base"

    def __init__(self, rng: random.Random):
        self.rng = rng

    def choose(self, nodes: Sequence[MECNode], exclude: int) -> MECNode:
        raise NotImplementedError


class RandomPolicy(ForwardPolicy):
    name = "random"

    def choose(self, nodes: Sequence[MECNode], exclude: int) -> MECNode:
        return self.rng.choice(_candidates(nodes, exclude))


class PowerOfTwoPolicy(ForwardPolicy):
    name = "power_of_two"

    def choose(self, nodes: Sequence[MECNode], exclude: int) -> MECNode:
        cands = _candidates(nodes, exclude)
        if len(cands) == 1:
            return cands[0]
        a, b = self.rng.sample(cands, 2)
        return a if a.queue.pending_work() <= b.queue.pending_work() else b


class LeastLoadedPolicy(ForwardPolicy):
    name = "least_loaded"

    def choose(self, nodes: Sequence[MECNode], exclude: int) -> MECNode:
        cands = _candidates(nodes, exclude)
        return min(cands, key=lambda n: (n.queue.pending_work(), self.rng.random()))


class RoundRobinPolicy(ForwardPolicy):
    """Deterministic cycling over *stable node ids*.

    The pointer indexes the global id space and skips the excluded node, so
    a given pointer value always means the same node.  (Indexing into the
    excluded-filtered candidate list — the previous behavior — silently
    shifted which node each pointer value meant whenever ``exclude``
    changed, starving some nodes.)
    """

    name = "round_robin"

    def __init__(self, rng: random.Random):
        super().__init__(rng)
        self._next = 0

    def choose(self, nodes: Sequence[MECNode], exclude: int) -> MECNode:
        n = len(nodes)
        for _ in range(n):
            node = nodes[self._next % n]
            self._next += 1
            if node.node_id != exclude:
                return node
        raise ValueError(f"no candidate besides node {exclude}")


FORWARD_POLICIES = {
    "random": RandomPolicy,
    "power_of_two": PowerOfTwoPolicy,
    "least_loaded": LeastLoadedPolicy,
    "round_robin": RoundRobinPolicy,
}


def make_policy(name: str, rng: random.Random) -> ForwardPolicy:
    try:
        return FORWARD_POLICIES[name](rng)
    except KeyError:
        raise ValueError(f"unknown forward policy {name!r}; "
                         f"options: {sorted(FORWARD_POLICIES)}") from None
