"""MEC-LB Simulator — discrete-event reproduction of the paper's §IV.

Faithful behaviors:

* users send requests to their nearest MEC node (``Request.origin_node``);
* admission is decided by the node's queue discipline (FIFO = SFA v1
  baseline, preferential = the paper's contribution);
* on rejection the request is forwarded to a randomly chosen neighbor
  (``max_forwards`` = 2 in all paper experiments); network/scheduling delays
  are neglected (``forward_delay`` = 0), as in the paper;
* a request that has exhausted its forwards is force-pushed and processed
  even if late (the paper uses the non-discarding SFA variant); the
  Beraldi [9] discard variant is available via ``discard_on_exhaust``;
* every service always takes its worst-case processing time.

The simulator is deterministic given (scenario, seed): arrival lists are
regenerated from the seed for every policy so all disciplines see an
identical workload, while forwarding randomness uses an independent stream.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
import statistics
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.block_queue import FastPreferentialQueue, PreferentialQueue
from repro.core.node import MECNode, QueueLike
from repro.core.policies import make_policy
from repro.core.queues import EDFQueue, FIFOQueue
from repro.core.request import Request
from repro.core.scenarios import DEFAULT_ARRIVAL_WINDOW, SCENARIOS, generate_requests


def make_queue(kind: str) -> QueueLike:
    if kind == "fifo":
        return FIFOQueue()
    if kind == "preferential":
        return FastPreferentialQueue()
    if kind == "preferential_faithful":
        return PreferentialQueue()
    if kind == "preferential_compact":
        # literal Alg.2 pseudo-code reading of the forced push (ablation)
        return FastPreferentialQueue(forced_compaction=True)
    if kind == "edf":
        return EDFQueue()
    raise ValueError(f"unknown queue kind {kind!r}")


@dataclasses.dataclass
class SimConfig:
    scenario: int = 1
    queue: str = "fifo"                  # fifo | preferential | preferential_faithful | edf
    forward_policy: str = "random"       # random | power_of_two | least_loaded | round_robin
    max_forwards: int = 2                # paper: M = 2
    forward_delay: float = 0.0           # paper neglects network delay
    discard_on_exhaust: bool = False     # Beraldi [9] variant
    arrival_window: float = DEFAULT_ARRIVAL_WINDOW
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    config: SimConfig
    total_requests: int
    processed: int
    met_deadline: int
    forwards: int
    discarded: int
    mean_response_time: float
    per_node_forwards: List[int]

    @property
    def met_rate(self) -> float:
        return self.met_deadline / max(1, self.total_requests)

    @property
    def forward_rate(self) -> float:
        """Fraction of the maximum possible referrals (paper Fig. 6)."""
        return self.forwards / max(1, self.total_requests * self.config.max_forwards)


_ARRIVAL, _COMPLETE = 0, 1


def run_simulation(config: SimConfig,
                   requests: Optional[Sequence[Request]] = None) -> SimResult:
    """Run one seeded simulation and return aggregate metrics."""
    n_nodes = len(SCENARIOS[config.scenario])
    nodes = [MECNode(i, make_queue(config.queue)) for i in range(n_nodes)]
    fwd_rng = random.Random((config.seed, "forwarding").__hash__())
    policy = make_policy(config.forward_policy, fwd_rng)

    if requests is None:
        requests = generate_requests(config.scenario, config.seed,
                                     config.arrival_window)
    total = len(requests)

    seq = itertools.count()
    heap: List = []
    for req in requests:
        heapq.heappush(heap, (req.arrival_time, next(seq), _ARRIVAL, req,
                              nodes[req.origin_node]))

    forwards = 0
    discarded = 0
    completed: List[Request] = []

    def dispatch(node: MECNode, now: float) -> None:
        req = node.start_next(now)
        if req is not None:
            heapq.heappush(heap, (node.busy_until, next(seq), _COMPLETE, req, node))

    while heap:
        now, _, kind, req, node = heapq.heappop(heap)
        if kind == _COMPLETE:
            node.complete(now)
            completed.append(req)
            dispatch(node, now)
            continue

        # ARRIVAL
        node.metrics.received += 1
        exhausted = req.forwards >= config.max_forwards
        forced = exhausted and not config.discard_on_exhaust
        if node.try_admit(req, now, forced=forced):
            dispatch(node, now)
        elif exhausted:
            discarded += 1
            node.metrics.discarded += 1
        else:
            req.forwards += 1
            forwards += 1
            node.metrics.forwards_out += 1
            target = policy.choose(nodes, exclude=node.node_id)
            heapq.heappush(heap, (now + config.forward_delay, next(seq),
                                  _ARRIVAL, req, target))

    met = sum(1 for r in completed if r.met_deadline)
    resp = [r.completion_time - r.arrival_time for r in completed
            if r.completion_time is not None]
    return SimResult(
        config=config,
        total_requests=total,
        processed=len(completed),
        met_deadline=met,
        forwards=forwards,
        discarded=discarded,
        mean_response_time=statistics.fmean(resp) if resp else 0.0,
        per_node_forwards=[n.metrics.forwards_out for n in nodes],
    )


@dataclasses.dataclass
class AggregateResult:
    met_rate_mean: float
    met_rate_stdev: float
    forward_rate_mean: float
    forward_rate_stdev: float
    mean_response_time: float
    n_seeds: int


def run_experiment(scenario: int, queue: str, *, n_seeds: int = 40,
                   forward_policy: str = "random",
                   arrival_window: float = DEFAULT_ARRIVAL_WINDOW,
                   max_forwards: int = 2,
                   discard_on_exhaust: bool = False,
                   base_seed: int = 0) -> AggregateResult:
    """Average of ``n_seeds`` simulations — the paper runs 40 per scenario."""
    met, fwd, resp = [], [], []
    for s in range(n_seeds):
        cfg = SimConfig(scenario=scenario, queue=queue,
                        forward_policy=forward_policy,
                        arrival_window=arrival_window,
                        max_forwards=max_forwards,
                        discard_on_exhaust=discard_on_exhaust,
                        seed=base_seed + s)
        res = run_simulation(cfg)
        met.append(res.met_rate)
        fwd.append(res.forward_rate)
        resp.append(res.mean_response_time)
    return AggregateResult(
        met_rate_mean=statistics.fmean(met),
        met_rate_stdev=statistics.stdev(met) if len(met) > 1 else 0.0,
        forward_rate_mean=statistics.fmean(fwd),
        forward_rate_stdev=statistics.stdev(fwd) if len(fwd) > 1 else 0.0,
        mean_response_time=statistics.fmean(resp),
        n_seeds=n_seeds,
    )
