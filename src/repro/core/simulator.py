"""MEC-LB Simulator — the paper-config adapter over the orchestration core.

Faithful behaviors (now implemented once, in
:class:`repro.orchestration.Orchestrator`; this module maps the paper's
experiment space onto that core and is golden-value guarded against the
pre-refactor event loop in tests/test_orchestration.py):

* users send requests to their nearest MEC node (``Request.origin_node``);
* admission is decided by the node's queue discipline (FIFO = SFA v1
  baseline, preferential = the paper's contribution);
* on rejection the request is forwarded to a randomly chosen neighbor
  (``max_forwards`` = 2 in all paper experiments); network/scheduling delays
  are neglected (``forward_delay`` = 0), as in the paper;
* a request that has exhausted its forwards is force-pushed and processed
  even if late (the paper uses the non-discarding SFA variant); the
  Beraldi [9] discard variant is available via ``discard_on_exhaust``;
* every service always takes its worst-case processing time;
* the cluster is a homogeneous full mesh (``Topology.full_mesh``) — use the
  orchestration API directly for rings, stars, two-tier or heterogeneous
  clusters (DESIGN.md §4 has the migration table).

The simulator is deterministic given (scenario, seed): arrival lists are
regenerated from the seed for every policy so all disciplines see an
identical workload, while forwarding randomness uses an independent stream.
"""
from __future__ import annotations

import dataclasses
import random
import statistics
from typing import List, Optional, Sequence

from repro.core.block_queue import FastPreferentialQueue, PreferentialQueue
from repro.core.node import QueueLike
from repro.core.queues import EDFQueue, FIFOQueue
from repro.core.request import Request
from repro.core.scenarios import DEFAULT_ARRIVAL_WINDOW, SCENARIOS, generate_requests


def make_queue(kind: str) -> QueueLike:
    if kind == "fifo":
        return FIFOQueue()
    if kind == "preferential":
        return FastPreferentialQueue()
    if kind == "preferential_faithful":
        return PreferentialQueue()
    if kind == "preferential_compact":
        # literal Alg.2 pseudo-code reading of the forced push (ablation)
        return FastPreferentialQueue(forced_compaction=True)
    if kind == "edf":
        return EDFQueue()
    raise ValueError(f"unknown queue kind {kind!r}")


@dataclasses.dataclass
class SimConfig:
    scenario: int = 1
    queue: str = "fifo"                  # fifo | preferential | preferential_faithful | edf
    forward_policy: str = "random"       # random | power_of_two | least_loaded | round_robin
    max_forwards: int = 2                # paper: M = 2
    forward_delay: float = 0.0           # paper neglects network delay
    discard_on_exhaust: bool = False     # Beraldi [9] variant
    arrival_window: float = DEFAULT_ARRIVAL_WINDOW
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    config: SimConfig
    total_requests: int
    processed: int
    met_deadline: int
    forwards: int
    discarded: int
    mean_response_time: float
    per_node_forwards: List[int]

    @property
    def met_rate(self) -> float:
        return self.met_deadline / max(1, self.total_requests)

    @property
    def forward_rate(self) -> float:
        """Fraction of the maximum possible referrals (paper Fig. 6)."""
        return self.forwards / max(1, self.total_requests * self.config.max_forwards)


def run_simulation(config: SimConfig,
                   requests: Optional[Sequence[Request]] = None) -> SimResult:
    """Run one seeded simulation and return aggregate metrics.

    Thin adapter: builds the paper's homogeneous full mesh and delegates the
    event loop to :class:`repro.orchestration.Orchestrator`.
    """
    # Imported here, not at module top: repro.core.__init__ imports this
    # module, and the orchestration modules import repro.core submodules.
    from repro.orchestration.orchestrator import Orchestrator
    from repro.orchestration.router import Router
    from repro.orchestration.topology import Topology

    topology = Topology.full_mesh(len(SCENARIOS[config.scenario]))
    # str seeds hash via sha512 inside random.Random, so the forwarding
    # stream is stable across processes (tuple.__hash__ of a str-bearing
    # tuple is NOT — it varies with PYTHONHASHSEED).
    fwd_rng = random.Random(f"forwarding:{config.seed}")
    router = Router(topology, config.forward_policy, rng=fwd_rng)
    orch = Orchestrator(topology, lambda: make_queue(config.queue), router,
                        max_forwards=config.max_forwards,
                        forward_delay=config.forward_delay,
                        discard_on_exhaust=config.discard_on_exhaust)

    if requests is None:
        requests = generate_requests(config.scenario, config.seed,
                                     config.arrival_window)
    res = orch.run(requests)
    return SimResult(
        config=config,
        total_requests=res.total_requests,
        processed=res.processed,
        met_deadline=res.met_deadline,
        forwards=res.forwards,
        discarded=res.discarded,
        mean_response_time=res.mean_response_time,
        per_node_forwards=[m.forwards_out for m in res.per_node],
    )


@dataclasses.dataclass
class AggregateResult:
    met_rate_mean: float
    met_rate_stdev: float
    forward_rate_mean: float
    forward_rate_stdev: float
    mean_response_time: float
    n_seeds: int


def run_experiment(scenario: int, queue: str, *, n_seeds: int = 40,
                   forward_policy: str = "random",
                   arrival_window: float = DEFAULT_ARRIVAL_WINDOW,
                   max_forwards: int = 2,
                   discard_on_exhaust: bool = False,
                   base_seed: int = 0) -> AggregateResult:
    """Average of ``n_seeds`` simulations — the paper runs 40 per scenario."""
    met, fwd, resp = [], [], []
    for s in range(n_seeds):
        cfg = SimConfig(scenario=scenario, queue=queue,
                        forward_policy=forward_policy,
                        arrival_window=arrival_window,
                        max_forwards=max_forwards,
                        discard_on_exhaust=discard_on_exhaust,
                        seed=base_seed + s)
        res = run_simulation(cfg)
        met.append(res.met_rate)
        fwd.append(res.forward_rate)
        resp.append(res.mean_response_time)
    return AggregateResult(
        met_rate_mean=statistics.fmean(met),
        met_rate_stdev=statistics.stdev(met) if len(met) > 1 else 0.0,
        forward_rate_mean=statistics.fmean(fwd),
        forward_rate_stdev=statistics.stdev(fwd) if len(fwd) > 1 else 0.0,
        mean_response_time=statistics.fmean(resp),
        n_seeds=n_seeds,
    )
