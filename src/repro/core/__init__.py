"""Core contribution of Boing et al. (2022): deadline-aware distributed load
orchestration with a preferential (time-block) queue, plus the MEC-LB
simulation environment used for the paper's experiments."""

from repro.core.block_queue import Block, FastPreferentialQueue, PreferentialQueue
from repro.core.node import MECNode, NodeMetrics
from repro.core.queues import EDFQueue, FIFOQueue
from repro.core.request import Request, Service, SERVICES, SERVICE_ORDER
from repro.core.scenarios import (DEFAULT_ARRIVAL_WINDOW, SCENARIOS,
                                  generate_requests, total_requests)
from repro.core.simulator import (AggregateResult, SimConfig, SimResult,
                                  make_queue, run_experiment, run_simulation)

__all__ = [
    "Block", "FastPreferentialQueue", "PreferentialQueue",
    "MECNode", "NodeMetrics", "EDFQueue", "FIFOQueue",
    "Request", "Service", "SERVICES", "SERVICE_ORDER",
    "DEFAULT_ARRIVAL_WINDOW", "SCENARIOS", "generate_requests", "total_requests",
    "AggregateResult", "SimConfig", "SimResult",
    "make_queue", "run_experiment", "run_simulation",
]
