"""Baseline queues sharing the preferential queue's interface.

* :class:`FIFOQueue` — the Sequential Forwarding Algorithm v1 baseline
  (Beraldi et al. [12], as used by the paper): left-packed append-only queue;
  a request is admitted iff the node can finish it within its deadline given
  the work already queued; otherwise it is forwarded (handled by the node);
  after M forwards it is force-appended and processed late (the paper uses
  the non-discarding variant).
* :class:`EDFQueue` — classic earliest-deadline-first with an exact
  admission test (beyond-paper comparison point).  Requests are kept sorted
  by absolute deadline; admission simulates the post-insertion schedule and
  accepts iff no admitted request (old or new) misses its deadline.

All queues expose ``push(request, cpu_free_time, forced) -> bool``,
``pop() -> Request | None``, ``__len__``, ``pending_work()`` and
``scheduled_blocks(cpu_free_time)`` (the (start, end) schedule the admission
test committed to — consumed by the router's ``batched_feasible`` scoring)
so the simulator and the serving engine treat them uniformly.
"""
from __future__ import annotations

import bisect
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.core.request import Request

_EPS = 1e-9


class FIFOQueue:
    """SFA v1 FIFO queue with deadline admission test (paper baseline)."""

    def __init__(self) -> None:
        self._items: Deque[Request] = deque()
        self._total_work = 0.0

    def __len__(self) -> int:
        return len(self._items)

    def is_empty(self) -> bool:
        return not self._items

    def pending_work(self) -> float:
        return self._total_work

    def push(self, request: Request, cpu_free_time: float, forced: bool = False) -> bool:
        completion = cpu_free_time + self._total_work + request.proc_time
        if completion > request.deadline + _EPS and not forced:
            return False
        self._items.append(request)
        self._total_work += request.proc_time
        return True

    def peek(self) -> Optional[Request]:
        return self._items[0] if self._items else None

    def pop(self) -> Optional[Request]:
        if not self._items:
            return None
        req = self._items.popleft()
        self._total_work -= req.proc_time
        return req

    def scheduled_blocks(self, cpu_free_time: float) -> List[Tuple[float, float]]:
        """Contiguous run-to-completion schedule starting at ``cpu_free_time``."""
        out, t = [], cpu_free_time
        for r in self._items:
            out.append((t, t + r.proc_time))
            t += r.proc_time
        return out


class EDFQueue:
    """Earliest-deadline-first with exact schedulability admission test.

    Admitted requests are kept sorted by absolute deadline (the *main*
    segment).  A forced push that cannot be scheduled feasibly goes to a
    late *overflow* segment executed after the main segment — analogous to
    the preferential queue's compact-and-append forced semantics: already
    admitted deadlines are never disturbed, the forced request runs late.
    """

    def __init__(self) -> None:
        self._main: List[Request] = []            # sorted by absolute deadline
        self._deadlines: List[float] = []
        self._overflow: List[Request] = []        # forced, already-late, FIFO
        self._total_work = 0.0

    def __len__(self) -> int:
        return len(self._main) + len(self._overflow)

    def is_empty(self) -> bool:
        return not self._main and not self._overflow

    def pending_work(self) -> float:
        return self._total_work

    def push(self, request: Request, cpu_free_time: float, forced: bool = False) -> bool:
        idx = bisect.bisect_right(self._deadlines, request.deadline)
        if self._schedulable_with(request, idx, cpu_free_time):
            self._main.insert(idx, request)
            self._deadlines.insert(idx, request.deadline)
            self._total_work += request.proc_time
            return True
        if not forced:
            return False
        self._overflow.append(request)
        self._total_work += request.proc_time
        return True

    def _schedulable_with(self, request: Request, idx: int, cpu_free_time: float) -> bool:
        t = cpu_free_time
        for r in self._main[:idx]:
            t += r.proc_time
        t += request.proc_time
        if t > request.deadline + _EPS:
            return False
        for r in self._main[idx:]:
            t += r.proc_time
            if t > r.deadline + _EPS:
                return False
        return True

    def peek(self) -> Optional[Request]:
        if self._main:
            return self._main[0]
        return self._overflow[0] if self._overflow else None

    def pop(self) -> Optional[Request]:
        if self._main:
            self._deadlines.pop(0)
            req = self._main.pop(0)
            self._total_work -= req.proc_time
            return req
        if self._overflow:
            req = self._overflow.pop(0)
            self._total_work -= req.proc_time
            return req
        return None

    def scheduled_blocks(self, cpu_free_time: float) -> List[Tuple[float, float]]:
        """Contiguous schedule: main segment in deadline order, then overflow."""
        out, t = [], cpu_free_time
        for r in list(self._main) + list(self._overflow):
            out.append((t, t + r.proc_time))
            t += r.proc_time
        return out


QUEUE_TYPES = {
    "fifo": FIFOQueue,
    "edf": EDFQueue,
}
