"""MEC node model: one queue + one worst-case-deterministic processor.

The paper assumes all MEC nodes have equivalent computing resources and that
every service hits its worst-case processing time, so the processor model is
a deterministic single server.  The *ledger* (queue) decides admission; the
executor is work-conserving (starts the head request the moment the CPU is
free, regardless of the block's scheduled-late position — see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

from repro.core.request import Request


class QueueLike(Protocol):
    def push(self, request: Request, cpu_free_time: float, forced: bool = ...) -> bool: ...
    def pop(self) -> Optional[Request]: ...
    def pending_work(self) -> float: ...
    def __len__(self) -> int: ...


@dataclasses.dataclass
class NodeMetrics:
    received: int = 0          # arrivals incl. forwarded-in
    admitted: int = 0
    forwards_out: int = 0
    forced_pushes: int = 0
    discarded: int = 0
    processed: int = 0
    met_deadline: int = 0


class MECNode:
    """One MEC node: admission queue + deterministic single-server CPU."""

    def __init__(self, node_id: int, queue: QueueLike):
        self.node_id = node_id
        self.queue = queue
        self.busy_until = 0.0
        self.active: Optional[Request] = None
        self.metrics = NodeMetrics()

    def cpu_free_time(self, now: float) -> float:
        """Absolute time at which the CPU will next be free."""
        return max(now, self.busy_until)

    def try_admit(self, request: Request, now: float, forced: bool) -> bool:
        ok = self.queue.push(request, self.cpu_free_time(now), forced=forced)
        if ok:
            self.metrics.admitted += 1
            if forced:
                self.metrics.forced_pushes += 1
        return ok

    def start_next(self, now: float) -> Optional[Request]:
        """Pop and start the head request if the CPU is idle. Returns it."""
        if self.active is not None or now < self.busy_until:
            return None
        req = self.queue.pop()
        if req is None:
            return None
        self.active = req
        self.busy_until = now + req.proc_time
        return req

    def complete(self, now: float) -> Request:
        req = self.active
        assert req is not None
        req.completion_time = now
        req.served_by = self.node_id
        self.active = None
        self.metrics.processed += 1
        if req.met_deadline:
            self.metrics.met_deadline += 1
        return req
