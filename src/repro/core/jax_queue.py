"""JAX-native preferential queue: the paper's admission test on the
accelerator.

The host-side queue (core/block_queue.py) decides one admission at a time.
At pod scale the orchestrator wants to score MANY candidate placements at
once — e.g. "which of 16 replica groups can serve each of these 64
requests within deadline?" — as one device call.  This module re-derives
the preferential-queue math (DESIGN.md §2) as fixed-capacity array ops:

* the ledger is (starts, ends, sizes, n) arrays sorted by time;
* the feasibility test is the same two-bisect search as
  FastPreferentialQueue (searchsorted + prefix sums);
* the Fig. 2c-d cascade left-shift has a closed form — after inserting at
  position j with right edge ``cap``::

      new_end_i = min(end_i, cap - p_new - sum(sizes[i+1 .. j-1]))   (i < j)

  computed with one reversed cumulative sum — so a push is fully
  vectorized (no sequential pointer walk);
* ``feasible_batch`` vmaps the test over candidate requests (read-only),
  which is what the deadline-aware engine uses for replica scoring.

Property-tested against the host queue in tests/test_jax_queue.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

BIG = 1e30


class Ledger(NamedTuple):
    starts: jnp.ndarray       # (N,) f32, +BIG past n
    ends: jnp.ndarray         # (N,) f32, +BIG past n
    sizes: jnp.ndarray        # (N,) f32, 0 past n
    n: jnp.ndarray            # scalar int32


def empty_ledger(capacity: int) -> Ledger:
    return Ledger(
        starts=jnp.full((capacity,), BIG, jnp.float32),
        ends=jnp.full((capacity,), BIG, jnp.float32),
        sizes=jnp.zeros((capacity,), jnp.float32),
        n=jnp.zeros((), jnp.int32),
    )


def _search(led: Ledger, p, d, cpu_free) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                  jnp.ndarray]:
    """(feasible, position j, right edge cap) — mirrors
    FastPreferentialQueue._search_alloc_space."""
    starts, ends, sizes, n = led
    idx = jnp.arange(starts.shape[0])
    # the ledger is time-sorted (+BIG padded), so searchsorted == masked
    # count — one vector reduce instead of a sequential bisect loop
    cap_idx = jnp.sum((starts < d).astype(jnp.int32))  # first start >= d
    e_hi = jnp.sum((ends < d).astype(jnp.int32))       # count of ends < d

    # interior gaps: position i (1..n-1) has a gap iff starts[i] > ends[i-1]
    prev_ends = jnp.concatenate([jnp.array([-BIG], jnp.float32), ends[:-1]])
    has_gap = (starts > prev_ends) & (idx >= 1) & (idx < n)
    gap_ok = has_gap & (idx <= e_hi)
    prev_gap = jnp.max(jnp.where(gap_ok, idx, 0))

    no_straddle = e_hi >= cap_idx
    j = jnp.where(no_straddle, e_hi, prev_gap)
    start_j = jnp.where(j < n, starts[jnp.minimum(j, starts.shape[0] - 1)],
                        BIG)
    cap = jnp.where(no_straddle, d, jnp.minimum(start_j, d))
    # j == 0 straddle fallback: front window
    start0 = jnp.where(n > 0, starts[0], BIG)
    cap = jnp.where(~no_straddle & (prev_gap == 0),
                    jnp.minimum(start0, d), cap)
    j = jnp.where(~no_straddle & (prev_gap == 0), 0, j)

    pw = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(sizes)])
    pw_j = pw[jnp.minimum(j, pw.shape[0] - 1)]
    feasible = cap - (cpu_free + pw_j) >= p - 1e-6
    front_ok = cap > cpu_free
    return feasible & front_ok, j, cap


@functools.partial(jax.jit, static_argnames=())
def feasible(led: Ledger, p: jnp.ndarray, d: jnp.ndarray,
             cpu_free: jnp.ndarray) -> jnp.ndarray:
    """Scalar admission test (same semantics as the host queue's push
    without mutation)."""
    ok, _, _ = _search(led, p, d, cpu_free)
    return ok


@jax.jit
def feasible_batch(led: Ledger, ps: jnp.ndarray, ds: jnp.ndarray,
                   cpu_free: jnp.ndarray) -> jnp.ndarray:
    """Vectorized admission scoring: (K,) proc times × (K,) deadlines
    against one ledger — one device call for a whole arrival batch."""
    return jax.vmap(lambda p, d: feasible(led, p, d, cpu_free))(ps, ds)


@jax.jit
def feasible_nodes(leds: Ledger, ps: jnp.ndarray, d: jnp.ndarray,
                   cpu_frees: jnp.ndarray) -> jnp.ndarray:
    """Cross-node companion of :func:`feasible_batch`: ONE request scored
    against K candidate nodes' ledgers in a single device call.

    ``leds`` holds stacked (K, N) arrays with a (K,) ``n``; ``cpu_frees``
    is (K,).  ``ps`` is (K,): the request's processing time per candidate,
    already divided by each node's speed factor on heterogeneous clusters.
    Returns a (K,) bool mask — which candidates can still admit the
    request within its deadline.  This is what the router's
    ``batched_feasible`` policy calls per forwarding decision.
    """
    return jax.vmap(lambda led, p, cf: feasible(led, p, d, cf))(
        leds, ps, cpu_frees)


def insert_at(starts: jnp.ndarray, ends: jnp.ndarray, sizes: jnp.ndarray,
              head, n, feasible, forced_ok, j, cap, p, cpu_free,
              meta: Tuple[jnp.ndarray, ...] = (),
              meta_vals: Tuple[jnp.ndarray, ...] = ()):
    """Apply an admission on one ledger row at a pre-computed ``(j, cap)``
    slot/window pair (the quantities :func:`_search` produces).

    The single home of the closed-form Fig. 2c-d cascade: a feasible insert
    right-aligns the new block at ``cap`` and left-shifts earlier blocks by
    their cumulative slack; ``forced_ok`` appends plainly after the tail
    (the host queue's forced push with ``forced_compaction=False`` — the
    gap structure survives, the block runs late) and never moves earlier
    blocks.  Rows may be *head-pointer* rows (retired prefix ``[0, head)``
    holding -BIG/0; live blocks in ``[head, head + n)`` — the fleet
    simulator's layout); ``head == 0`` is a plain :class:`Ledger` row.
    ``meta`` is a tuple of per-slot (N,) arrays (e.g. request ids) that
    ride through the same insertion shift, with ``meta_vals`` written into
    the new slot.

    Returns ``(starts, ends, sizes, admitted, meta)``; callers bump their
    block count by ``admitted``.
    """
    N = starts.shape[0]
    admitted = feasible | forced_ok
    tail = head + n
    tail_end = jnp.where(n > 0, ends[jnp.clip(tail - 1, 0, N - 1)], cpu_free)
    jj = jnp.where(feasible, j, tail)
    right = jnp.where(feasible, cap, tail_end + p)
    new_start = right - p
    idx = jnp.arange(N)

    # cascade left-shift bound: work of blocks strictly between i and j
    # (suffix sums of sizes[:j]) caps each earlier block's new end; a
    # forced append never shifts
    sz_before = jnp.where(idx < jj, sizes, 0.0)
    between = jnp.sum(sz_before) - jnp.cumsum(sz_before)
    bound = jnp.where(feasible, new_start - between, BIG)
    new_ends = jnp.where(idx < jj, jnp.minimum(ends, bound), ends)
    new_starts = jnp.where(idx < jj, new_ends - sizes, starts)
    src = jnp.clip(idx - 1, 0, N - 1)          # entries >= j shift right

    def ins(pre, at_j, orig):
        out = jnp.where(idx < jj, pre,
                        jnp.where(idx == jj, jnp.asarray(at_j, orig.dtype),
                                  pre[src]))
        return jnp.where(admitted, out, orig)

    meta_out = tuple(ins(m, v, m) for m, v in zip(meta, meta_vals))
    return (ins(new_starts, new_start, starts), ins(new_ends, right, ends),
            ins(sizes, p, sizes), admitted, meta_out)


def admit(led: Ledger, p: jnp.ndarray, d: jnp.ndarray, cpu_free: jnp.ndarray,
          forced: jnp.ndarray = False, meta: Tuple[jnp.ndarray, ...] = (),
          meta_vals: Tuple[jnp.ndarray, ...] = ()
          ) -> Tuple[Ledger, jnp.ndarray, jnp.ndarray,
                     Tuple[jnp.ndarray, ...]]:
    """Generalized push: the host queue's full admission semantics.

    Tries the feasible right-aligned insert first (identical to
    :func:`push`); when that fails and ``forced`` is set, falls back to the
    tail append — both applied through :func:`insert_at`, which the fleet
    simulator also calls directly on its head-pointer rows with
    pre-computed search results.

    Returns ``(ledger, admitted, was_forced, meta)``.  A forced push on a
    full ledger fails (``admitted == False``) — fixed-capacity arrays cannot
    grow like the host's Python list; callers size ``capacity`` generously
    and surface the overflow.
    """
    starts, ends, sizes, n = led
    N = starts.shape[0]
    has_room = n < N
    ok, j, cap = _search(led, p, d, cpu_free)
    ok = ok & has_room
    was_forced = jnp.asarray(forced) & ~ok & has_room
    new_starts, new_ends, new_sizes, admitted, meta_out = insert_at(
        starts, ends, sizes, jnp.int32(0), n, ok, was_forced, j, cap, p,
        cpu_free, meta, meta_vals)
    out = Ledger(starts=new_starts, ends=new_ends, sizes=new_sizes,
                 n=jnp.where(admitted, n + 1, n))
    return out, admitted, was_forced, meta_out


@jax.jit
def push(led: Ledger, p: jnp.ndarray, d: jnp.ndarray,
         cpu_free: jnp.ndarray) -> Tuple[Ledger, jnp.ndarray]:
    """Admit if feasible; returns (new ledger, admitted flag).

    The cascade left-shift is closed-form: suffix work between each block
    and the insertion point bounds its new end (see :func:`admit`, of which
    this is the unforced, metadata-free special case).
    """
    out, ok, _, _ = admit(led, p, d, cpu_free, forced=False)
    return out, ok


@jax.jit
def push_nodes(leds: Ledger, ps: jnp.ndarray, ds: jnp.ndarray,
               cpu_frees: jnp.ndarray, forced: jnp.ndarray
               ) -> Tuple[Ledger, jnp.ndarray, jnp.ndarray]:
    """Stacked companion of :func:`push`/:func:`admit`: admit one request
    per node into K stacked ledgers in a single device call.

    ``leds`` holds stacked (K, N) arrays with a (K,) ``n``; ``ps``, ``ds``,
    ``cpu_frees`` and ``forced`` are (K,).  Returns ``(ledgers, admitted,
    was_forced)``.  Functionally pure — safe to wrap in a donated jit step.
    """
    def one(led, p, d, cf, f):
        out, ok, wf, _ = admit(led, p, d, cf, forced=f)
        return out, ok, wf
    return jax.vmap(one)(leds, ps, ds, cpu_frees, forced)


# ---------------------------------------------------------------------------
# Sorted event queue — the device mirror of the orchestrator's event heap.
# A compact (B,) buffer of keys (event times) plus parallel value arrays,
# kept ascending; the head (index 0) is always the earliest pending event.
# Both ops are O(B) where-selects, so they scan/vmap like everything else.
# ---------------------------------------------------------------------------
def event_push(keys: jnp.ndarray, vals: Tuple[jnp.ndarray, ...],
               n: jnp.ndarray, key, val_new: Tuple, active
               ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...],
                          jnp.ndarray, jnp.ndarray]:
    """Stable sorted insert into a compact event buffer.

    The insertion slot comes from a masked searchsorted with side='right'
    (``sum(keys <= key)`` over the live prefix), so an event lands *after*
    every already-buffered event with an equal key — exactly the host
    heap's ``(time, seq)`` tie-break, where ``seq`` is monotone in push
    order.  ``vals`` is a tuple of parallel (B,) arrays that ride the same
    shift; ``active=False`` makes the whole op a no-op (scan steps that do
    not emit an event).

    Returns ``(keys, vals, n, dropped)`` — ``dropped`` is True when the
    push was active but the buffer was full (callers surface it as a
    metric; it must never be silent).
    """
    B = keys.shape[0]
    idx = jnp.arange(B)
    room = n < B
    do = jnp.asarray(active) & room
    pos = jnp.sum(((keys <= key) & (idx < n)).astype(jnp.int32))
    src = jnp.clip(idx - 1, 0, B - 1)

    def ins(a, v):
        out = jnp.where(idx < pos, a,
                        jnp.where(idx == pos, jnp.asarray(v, a.dtype),
                                  a[src]))
        return jnp.where(do, out, a)

    return (ins(keys, key), tuple(ins(a, v) for a, v in zip(vals, val_new)),
            n + do.astype(n.dtype), jnp.asarray(active) & ~room)


def event_pop(keys: jnp.ndarray, vals: Tuple[jnp.ndarray, ...],
              n: jnp.ndarray, active
              ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...], jnp.ndarray]:
    """Drop the head (earliest) event; the vacated tail slot refills with
    ``+BIG`` keys / zero values so the buffer stays sorted and reads past
    ``n`` stay inert.  ``active=False`` is a no-op.  Callers read the head
    fields (``keys[0]``, ``vals[i][0]``) *before* popping."""
    do = jnp.asarray(active)

    def shift(a, fill):
        out = jnp.concatenate([a[1:], jnp.full((1,), fill, a.dtype)])
        return jnp.where(do, out, a)

    return (shift(keys, BIG), tuple(shift(a, 0) for a in vals),
            n - do.astype(n.dtype))


@jax.jit
def pop(led: Ledger) -> Tuple[Ledger, jnp.ndarray]:
    """Remove the head block; returns (ledger, popped size or 0)."""
    starts, ends, sizes, n = led
    has = n > 0
    size0 = jnp.where(has, sizes[0], 0.0)
    out = Ledger(
        starts=jnp.where(has, jnp.concatenate([starts[1:], jnp.array([BIG])]),
                         starts),
        ends=jnp.where(has, jnp.concatenate([ends[1:], jnp.array([BIG])]),
                       ends),
        sizes=jnp.where(has, jnp.concatenate([sizes[1:], jnp.array([0.0])]),
                        sizes),
        n=jnp.where(has, n - 1, n),
    )
    return out, size0
