"""Experiment scenarios — Tables I and II of the paper.

Scenario 1-2 have 3 MEC nodes; scenario 3 adds 3 lightly-loaded nodes.
Request counts are per (node, service) exactly as published.  The paper does
not specify the arrival process; we draw i.i.d. uniform arrival times over a
window ``arrival_window`` (calibrated in EXPERIMENTS.md so scenario 1 lands
in the paper's "<20% deadlines met" overload regime).
"""
from __future__ import annotations

import random
from typing import Dict, List

from repro.core.request import Request, SERVICES, SERVICE_ORDER

# Table II. counts[node_index][service_name]
SCENARIOS: Dict[int, List[Dict[str, int]]] = {
    1: [
        {"S1": 500, "S2": 300, "S3": 200, "S4": 500, "S5": 300, "S6": 200},
        {"S1": 200, "S2": 300, "S3": 500, "S4": 200, "S5": 300, "S6": 500},
        {"S1": 300, "S2": 500, "S3": 200, "S4": 300, "S5": 500, "S6": 200},
    ],
    2: [
        {"S1": 250, "S2": 300, "S3": 700, "S4": 250, "S5": 300, "S6": 700},
        {"S1": 100, "S2": 300, "S3": 1000, "S4": 100, "S5": 300, "S6": 1000},
        {"S1": 150, "S2": 500, "S3": 700, "S4": 150, "S5": 500, "S6": 700},
    ],
    3: [
        {"S1": 250, "S2": 300, "S3": 700, "S4": 250, "S5": 300, "S6": 700},
        {"S1": 100, "S2": 300, "S3": 1000, "S4": 100, "S5": 300, "S6": 1000},
        {"S1": 150, "S2": 500, "S3": 700, "S4": 150, "S5": 500, "S6": 700},
        {"S1": 100, "S2": 100, "S3": 100, "S4": 100, "S5": 100, "S6": 100},
        {"S1": 100, "S2": 100, "S3": 100, "S4": 100, "S5": 100, "S6": 100},
        {"S1": 100, "S2": 100, "S3": 100, "S4": 100, "S5": 100, "S6": 100},
    ],
}

# Paper totals used for the Fig. 5/6 percentages.
TOTAL_REQUESTS = {1: 6000, 2: 8000, 3: 9800}

# Calibrated so scenario 1 sits in the paper's "<20% met" overload regime
# with a preferential-vs-FIFO gap matching the published +2.92pp; see
# EXPERIMENTS.md §Paper-reproduction for the sensitivity sweep.
DEFAULT_ARRIVAL_WINDOW = 110_000.0


def total_requests(scenario: int) -> int:
    return sum(sum(c.values()) for c in SCENARIOS[scenario])


def generate_requests(scenario: int, seed: int,
                      arrival_window: float = DEFAULT_ARRIVAL_WINDOW
                      ) -> List[Request]:
    """Deterministic request list for one simulation seed.

    The same (scenario, seed, window) always yields identical arrival times
    and service mix, so different queue disciplines are compared on an
    identical workload — the paper's "a copy of the requisition list
    simulates each load distribution approach".
    """
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario}; options {sorted(SCENARIOS)}")
    rng = random.Random((scenario, seed, round(arrival_window)).__hash__())
    requests: List[Request] = []
    for node_idx, counts in enumerate(SCENARIOS[scenario]):
        for sname in SERVICE_ORDER:
            svc = SERVICES[sname]
            for _ in range(counts.get(sname, 0)):
                requests.append(Request(
                    service=svc,
                    arrival_time=rng.uniform(0.0, arrival_window),
                    origin_node=node_idx,
                ))
    requests.sort(key=lambda r: (r.arrival_time, r.rid))
    return requests
