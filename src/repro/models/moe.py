"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

TPU-native formulation (DESIGN.md §Hardware-adaptation): tokens are sorted by
expert id and gathered into a dense (E, C, d) buffer so the expert FFN is one
grouped einsum on the MXU — the buffer's expert axis shards over the
``model`` mesh axis (expert parallelism) and GSPMD turns the gather/scatter
into the canonical MoE all-to-alls.  Tokens over capacity are dropped
(GShard-style); the residual stream carries them unchanged.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import swiglu


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float, min_capacity: int = 4) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts)
    c = max(min_capacity, c)
    return min(c, n_tokens)


def route_topk(router_logits: jnp.ndarray, top_k: int,
               n_real: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, E) logits -> (gates (T, K) fp32 normalized, experts (T, K) int32).

    ``n_real``: number of real experts — columns beyond it are padding
    (masked out of routing; see LMConfig.n_experts_pad).
    """
    if n_real is not None and n_real < router_logits.shape[-1]:
        col = jnp.arange(router_logits.shape[-1])
        router_logits = jnp.where(col[None, :] < n_real, router_logits, -1e30)
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32)


def load_balancing_loss(router_logits: jnp.ndarray, experts: jnp.ndarray,
                        n_experts: int) -> jnp.ndarray:
    """Switch-style aux loss: E * <fraction routed> . <mean router prob>."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(experts[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(me * ce)


def dispatch_indices(experts: jnp.ndarray, n_experts: int, cap: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch plan.

    experts: (T, K) int32.  Returns (expert_id (T*K,), slot (T*K,),
    keep (T*K,) bool) — token-copy i goes to buffer[expert_id[i], slot[i]]
    iff keep[i].
    """
    flat = experts.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    # rank of each copy within its expert group
    ranks = jnp.arange(flat.shape[0]) - jnp.searchsorted(
        sorted_e, sorted_e, side="left")
    slot = jnp.zeros_like(flat).at[order].set(ranks)
    keep = slot < cap
    return flat, slot.astype(jnp.int32), keep


def _local_dispatch_ffn(x: jnp.ndarray, router_w: jnp.ndarray,
                        w_gate: jnp.ndarray, w_up: jnp.ndarray,
                        w_down: jnp.ndarray, *, top_k: int,
                        capacity_factor: float, n_experts: int,
                        expert_offset,
                        n_real: Optional[int] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-shard MoE: local tokens, local expert slice (E_loc, d, f).

    ``expert_offset`` is this shard's first expert id (0 when experts are
    replicated and only d_ff is sharded).  Returns the PARTIAL output (sum
    over the expert/ffn axis still required) and the local aux loss.
    """
    T, d = x.shape
    E_loc = w_gate.shape[0]
    n_real = n_real or n_experts
    logits = jnp.einsum("td,de->te", x, router_w,
                        preferred_element_type=jnp.float32)
    gates, experts = route_topk(logits, top_k, n_real=n_real)
    aux = load_balancing_loss(logits[:, :n_real], experts, n_real)
    cap = capacity(T, n_real, top_k, capacity_factor)

    eid, slot, keep = dispatch_indices(experts, n_experts, cap)
    tok = jnp.repeat(jnp.arange(T), top_k)
    mine = keep & (eid >= expert_offset) & (eid < expert_offset + E_loc)
    safe_e = jnp.where(mine, eid - expert_offset, 0)
    safe_s = jnp.where(mine, slot, 0)

    buf = jnp.zeros((E_loc, cap, d), x.dtype)
    contrib = jnp.where(mine[:, None], x[tok], 0).astype(x.dtype)
    buf = buf.at[safe_e, safe_s].add(contrib)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = swiglu(g, u)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)

    flat_gates = gates.reshape(-1)
    gathered = y[safe_e, safe_s]
    # combine in the activation dtype (bf16): halves the (T·K, d) combine
    # traffic and its backward all-reduce; the scatter-add accumulates in
    # f32 via the out buffer (§Perf A5)
    weighted = gathered * jnp.where(mine, flat_gates, 0.0
                                    )[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(
        weighted.astype(jnp.float32))
    return out.astype(x.dtype), aux


def moe_ffn_sharded(x: jnp.ndarray, router_w: jnp.ndarray,
                    w_gate: jnp.ndarray, w_up: jnp.ndarray,
                    w_down: jnp.ndarray, *, top_k: int,
                    capacity_factor: float, mesh, dp_axes, model_axis: str,
                    fsdp_axes, expert_sharded: bool,
                    n_real: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map MoE (beyond-baseline optimization, EXPERIMENTS.md §Perf).

    The einsum/GSPMD formulation sorts and scatters *globally*, which the
    partitioner lowers to catastrophic all-gathers (the dispatch tensors are
    token-count sized).  Here dispatch is LOCAL to each data shard:

    * tokens are replicated across the model axis, so each (data, model)
      device dispatches its own token shard to the experts it owns and a
      single psum over ``model`` combines expert contributions —
      the only cross-device traffic is one (T_loc, d) all-reduce per layer
      plus the (unavoidable) FSDP weight all-gathers;
    * ``expert_sharded``: experts split over ``model`` (E % mp == 0, kimi);
      otherwise each expert's d_ff is split (granite's 40 experts).
    """
    import functools as _ft
    from jax.sharding import PartitionSpec as P

    E = router_w.shape[-1]
    dp = tuple(dp_axes) if dp_axes else ()
    fa = (fsdp_axes,) if isinstance(fsdp_axes, str) else tuple(fsdp_axes or ())

    if expert_sharded:
        w_specs = P(model_axis, fa if fa else None, None)
        wd_spec = P(model_axis, None, fa if fa else None)
    else:
        w_specs = P(None, fa if fa else None, model_axis)
        wd_spec = P(None, model_axis, fa if fa else None)

    def local_fn(x_loc, rw, wg, wu, wd):
        if fa:
            wg = jax.lax.all_gather(wg, fa, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fa, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fa, axis=2, tiled=True)
        if expert_sharded:
            E_loc = wg.shape[0]
            off = jax.lax.axis_index(model_axis) * E_loc
        else:
            off = 0
        out, aux = _local_dispatch_ffn(
            x_loc, rw, wg, wu, wd, top_k=top_k,
            capacity_factor=capacity_factor, n_experts=E, expert_offset=off,
            n_real=n_real)
        out = jax.lax.psum(out, model_axis)
        return out, aux[None]

    out, aux = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp if dp else None, None), P(None, None),
                  w_specs, w_specs, wd_spec),
        out_specs=(P(dp if dp else None, None), P(dp if dp else None)),
    )(x, router_w, w_gate, w_up, w_down)
    return out, jnp.mean(aux)


def moe_ffn(x: jnp.ndarray, router_w: jnp.ndarray, w_gate: jnp.ndarray,
            w_up: jnp.ndarray, w_down: jnp.ndarray, *, top_k: int,
            capacity_factor: float,
            n_real: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (T, d); expert weights (E, d, f) / (E, f, d). Returns (out, aux)."""
    T, d = x.shape
    E = router_w.shape[-1]
    n_real = n_real or E
    logits = jnp.einsum("td,de->te", x, router_w,
                        preferred_element_type=jnp.float32)
    gates, experts = route_topk(logits, top_k)
    aux = load_balancing_loss(logits, experts, E)
    cap = capacity(T, E, top_k, capacity_factor)

    eid, slot, keep = dispatch_indices(experts, E, cap)
    tok = jnp.repeat(jnp.arange(T), top_k)
    safe_e = jnp.where(keep, eid, 0)
    safe_s = jnp.where(keep, slot, 0)

    # scatter tokens into the (E, C, d) buffer (dropped copies masked out)
    buf = jnp.zeros((E, cap, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[tok], 0).astype(x.dtype)
    buf = buf.at[safe_e, safe_s].add(contrib)

    # grouped expert FFN (SwiGLU)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = swiglu(g, u)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)

    # combine back with gates
    flat_gates = gates.reshape(-1)
    gathered = y[safe_e, safe_s]                     # (T*K, d)
    weighted = gathered.astype(jnp.float32) * jnp.where(
        keep, flat_gates, 0.0)[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(weighted)
    return out.astype(x.dtype), aux
