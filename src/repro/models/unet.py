"""SD 1.5 UNet (arXiv:2112.10752): latent-space epsilon predictor.

ch=320, mult (1,2,4,4), 2 ResBlocks/level, transformer (self+cross attn to a
77×768 text-context stub) at levels 0-2, timestep embedding, skip
connections.  NHWC layout; channels shard over ``model``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import UNetConfig
from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import common

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _levels(cfg: UNetConfig) -> List[int]:
    return [cfg.ch * m for m in cfg.ch_mult]


def _conv_def(k, cin, cout, dt, init="normal"):
    return common.ParamDef((k, k, cin, cout), init, dtype=dt)


def param_defs(cfg: UNetConfig) -> Dict[str, common.ParamDef]:
    dt = _dtype(cfg)
    ch = cfg.ch
    temb_d = ch * 4
    defs: Dict[str, common.ParamDef] = {
        "t_mlp/w1": common.ParamDef((ch, temb_d), dtype=dt),
        "t_mlp/b1": common.ParamDef((temb_d,), "zeros", dtype=dt),
        "t_mlp/w2": common.ParamDef((temb_d, temb_d), dtype=dt),
        "t_mlp/b2": common.ParamDef((temb_d,), "zeros", dtype=dt),
        "conv_in": _conv_def(3, cfg.latent_channels, ch, dt),
        "conv_out": _conv_def(3, ch, cfg.latent_channels, dt, "zeros"),
        "norm_out/scale": common.ParamDef((ch,), "ones", dtype=jnp.float32),
        "norm_out/bias": common.ParamDef((ch,), "zeros", dtype=jnp.float32),
    }

    def res_block(base, cin, cout):
        defs[f"{base}/n1/scale"] = common.ParamDef((cin,), "ones", dtype=jnp.float32)
        defs[f"{base}/n1/bias"] = common.ParamDef((cin,), "zeros", dtype=jnp.float32)
        defs[f"{base}/c1"] = _conv_def(3, cin, cout, dt)
        defs[f"{base}/temb_w"] = common.ParamDef((temb_d, cout), dtype=dt)
        defs[f"{base}/temb_b"] = common.ParamDef((cout,), "zeros", dtype=dt)
        defs[f"{base}/n2/scale"] = common.ParamDef((cout,), "ones", dtype=jnp.float32)
        defs[f"{base}/n2/bias"] = common.ParamDef((cout,), "zeros", dtype=jnp.float32)
        defs[f"{base}/c2"] = _conv_def(3, cout, cout, dt, "zeros")
        if cin != cout:
            defs[f"{base}/skip"] = _conv_def(1, cin, cout, dt)

    def attn_block(base, c):
        defs[f"{base}/norm/scale"] = common.ParamDef((c,), "ones", dtype=jnp.float32)
        defs[f"{base}/norm/bias"] = common.ParamDef((c,), "zeros", dtype=jnp.float32)
        for nm, shp in (("wq", (c, c)), ("wk", (c, c)), ("wv", (c, c)),
                        ("wo", (c, c)),
                        ("cq", (c, c)), ("ck", (cfg.ctx_dim, c)),
                        ("cv", (cfg.ctx_dim, c)), ("co", (c, c)),
                        ("ff1", (c, 4 * c)), ("ff2", (4 * c, c))):
            defs[f"{base}/{nm}"] = common.ParamDef(shp, dtype=dt)
        defs[f"{base}/ln1/scale"] = common.ParamDef((c,), "ones", dtype=jnp.float32)
        defs[f"{base}/ln1/bias"] = common.ParamDef((c,), "zeros", dtype=jnp.float32)
        defs[f"{base}/ln2/scale"] = common.ParamDef((c,), "ones", dtype=jnp.float32)
        defs[f"{base}/ln2/bias"] = common.ParamDef((c,), "zeros", dtype=jnp.float32)
        defs[f"{base}/ln3/scale"] = common.ParamDef((c,), "ones", dtype=jnp.float32)
        defs[f"{base}/ln3/bias"] = common.ParamDef((c,), "zeros", dtype=jnp.float32)

    chans = _levels(cfg)
    # encoder
    cin = cfg.ch
    for li, c in enumerate(chans):
        for bi in range(cfg.n_res_blocks):
            res_block(f"down{li}/res{bi}", cin, c)
            cin = c
            if li in cfg.attn_levels:
                attn_block(f"down{li}/attn{bi}", c)
        if li < len(chans) - 1:
            defs[f"down{li}/downsample"] = _conv_def(3, c, c, dt)
    # middle
    res_block("mid/res0", chans[-1], chans[-1])
    attn_block("mid/attn", chans[-1])
    res_block("mid/res1", chans[-1], chans[-1])
    # decoder (skip concat doubles input channels)
    for li in reversed(range(len(chans))):
        c = chans[li]
        for bi in range(cfg.n_res_blocks + 1):
            skip_c = _skip_channels(cfg)[li][bi]
            res_block(f"up{li}/res{bi}", cin + skip_c, c)
            cin = c
            if li in cfg.attn_levels:
                attn_block(f"up{li}/attn{bi}", c)
        if li > 0:
            defs[f"up{li}/upsample"] = _conv_def(3, c, c, dt)
    return defs


def _skip_channels(cfg: UNetConfig) -> Dict[int, List[int]]:
    """Channel count of each skip tensor consumed by the decoder."""
    chans = _levels(cfg)
    stack: List[int] = [cfg.ch]                      # conv_in output
    for li, c in enumerate(chans):
        for _ in range(cfg.n_res_blocks):
            stack.append(c)
        if li < len(chans) - 1:
            stack.append(c)                           # downsample output
    out: Dict[int, List[int]] = {}
    for li in reversed(range(len(chans))):
        out[li] = [stack.pop() for _ in range(cfg.n_res_blocks + 1)]
    return out


def param_specs(cfg): return common.param_specs(param_defs(cfg))
def init_params(cfg, key): return common.init_params(param_defs(cfg), key)


def param_logical(cfg: UNetConfig) -> Dict[str, Tuple]:
    log = {}
    for path, d in param_defs(cfg).items():
        if len(d.shape) == 4:       # conv: shard output channels
            log[path] = (None, None, None, "tp")
        elif len(d.shape) == 2:     # dense: shard columns
            log[path] = ("fsdp", "tp") if d.shape[0] >= 512 else (None, "tp")
        else:
            log[path] = tuple(None for _ in d.shape)
    return log


def _get(params, path):
    node = params
    for p in path.split("/"):
        node = node[p]
    return node


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _res_block(x, p, temb):
    h = common.group_norm(x, p["n1"]["scale"], p["n1"]["bias"])
    h = _conv(jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype), p["c1"])
    h = h + (jax.nn.silu(temb.astype(jnp.float32)).astype(x.dtype)
             @ p["temb_w"] + p["temb_b"])[:, None, None, :]
    h = common.group_norm(h, p["n2"]["scale"], p["n2"]["bias"])
    h = _conv(jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype), p["c2"])
    skip = _conv(x, p["skip"]) if "skip" in p else x
    return h + skip


def _attn_block(x, p, ctx, n_heads):
    B, H, W, C = x.shape
    hd = C // n_heads
    h0 = common.group_norm(x, p["norm"]["scale"], p["norm"]["bias"])
    h = h0.reshape(B, H * W, C)
    # self-attention
    y = common.layer_norm(h, p["ln1"]["scale"], p["ln1"]["bias"])
    q = (y @ p["wq"]).reshape(B, -1, n_heads, hd)
    k = (y @ p["wk"]).reshape(B, -1, n_heads, hd)
    v = (y @ p["wv"]).reshape(B, -1, n_heads, hd)
    o = attn.attention(q, k, v, causal=False, impl="chunked", q_chunk=1024)
    h = h + o.reshape(B, -1, C) @ p["wo"]
    # cross-attention to text context
    y = common.layer_norm(h, p["ln2"]["scale"], p["ln2"]["bias"])
    q = (y @ p["cq"]).reshape(B, -1, n_heads, hd)
    k = (ctx @ p["ck"]).reshape(B, -1, n_heads, hd)
    v = (ctx @ p["cv"]).reshape(B, -1, n_heads, hd)
    o = attn.attention_naive(q, k, v, causal=False)
    h = h + o.reshape(B, -1, C) @ p["co"]
    # feed-forward
    y = common.layer_norm(h, p["ln3"]["scale"], p["ln3"]["bias"])
    h = h + common.gelu(y @ p["ff1"]) @ p["ff2"]
    return x + h.reshape(B, H, W, C)


def forward(params: PyTree, latents: jnp.ndarray, t: jnp.ndarray,
            ctx: jnp.ndarray, cfg: UNetConfig) -> jnp.ndarray:
    """latents (B,h,w,4), t (B,), ctx (B,77,768) -> epsilon (B,h,w,4)."""
    x = latents.astype(_dtype(cfg))
    ctx = ctx.astype(_dtype(cfg))
    temb = common.timestep_embedding(t, cfg.ch).astype(_dtype(cfg))
    temb = jax.nn.silu((temb @ params["t_mlp"]["w1"] + params["t_mlp"]["b1"]
                        ).astype(jnp.float32)).astype(x.dtype)
    temb = temb @ params["t_mlp"]["w2"] + params["t_mlp"]["b2"]

    chans = _levels(cfg)
    x = _conv(x, params["conv_in"])
    skips = [x]
    for li, c in enumerate(chans):
        lvl = params[f"down{li}"]
        for bi in range(cfg.n_res_blocks):
            x = _res_block(x, lvl[f"res{bi}"], temb)
            if li in cfg.attn_levels:
                x = _attn_block(x, lvl[f"attn{bi}"], ctx, cfg.n_heads)
            skips.append(x)
        if li < len(chans) - 1:
            x = _conv(x, lvl["downsample"], stride=2)
            skips.append(x)
        x = shd.hint(x, "dp", "sp", None, "tp")

    mid = params["mid"]
    x = _res_block(x, mid["res0"], temb)
    x = _attn_block(x, mid["attn"], ctx, cfg.n_heads)
    x = _res_block(x, mid["res1"], temb)

    for li in reversed(range(len(chans))):
        lvl = params[f"up{li}"]
        for bi in range(cfg.n_res_blocks + 1):
            x = jnp.concatenate([x, skips.pop()], axis=-1)
            x = _res_block(x, lvl[f"res{bi}"], temb)
            if li in cfg.attn_levels:
                x = _attn_block(x, lvl[f"attn{bi}"], ctx, cfg.n_heads)
        if li > 0:
            B, H, W, C = x.shape
            x = jax.image.resize(x, (B, H * 2, W * 2, C), "nearest")
            x = _conv(x, lvl["upsample"])
        x = shd.hint(x, "dp", "sp", None, "tp")

    x = common.group_norm(x, params["norm_out"]["scale"], params["norm_out"]["bias"])
    x = _conv(jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype),
              params["conv_out"])
    return x


def loss_fn(params, batch, cfg: UNetConfig):
    from repro.models.dit import ddpm_alphas
    lat = batch["latents"].astype(jnp.float32)
    B = lat.shape[0]
    rng = jax.random.fold_in(jax.random.PRNGKey(0), batch["step"])
    t = jax.random.randint(jax.random.fold_in(rng, 1), (B,), 0, 1000)
    eps = jax.random.normal(jax.random.fold_in(rng, 2), lat.shape, jnp.float32)
    a = ddpm_alphas()[t][:, None, None, None]
    noised = jnp.sqrt(a) * lat + jnp.sqrt(1 - a) * eps
    pred = forward(params, noised, t, batch["ctx"], cfg).astype(jnp.float32)
    loss = jnp.mean(jnp.square(pred - eps))
    return loss, {"loss": loss}


def make_train_step(cfg: UNetConfig, opt_cfg):
    from repro.training.optimizer import adamw_update

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        return params, opt_state, dict(metrics, **opt_metrics)

    return train_step


def serve_step(params, latents, t, ctx, cfg: UNetConfig):
    return forward(params, latents, t, ctx, cfg)
