"""Vision Transformer (ViT-L/16, ViT-H/14) and DeiT-B (distillation token).

Patch-embedding is part of the model (vision pool rule).  Pre-LN blocks,
learned positional embeddings, GELU MLP, mean-free CLS-token classifier.
Pos-embeddings are sized for the config resolution and bilinearly
interpolated for other resolutions (cls_384 fine-tune shape).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ViTConfig
from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import common

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def param_defs(cfg: ViTConfig) -> Dict[str, common.ParamDef]:
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    p, c = cfg.patch, cfg.in_channels
    dt = _dtype(cfg)
    n_extra = 1 + int(cfg.distill_token)
    n_tok = (cfg.img_res // p) ** 2 + n_extra
    defs = {
        "patch_embed/w": common.ParamDef((p, p, c, d), dtype=dt),
        "patch_embed/b": common.ParamDef((d,), "zeros", dtype=dt),
        "cls_token": common.ParamDef((n_extra, d), "zeros", dtype=dt),
        "pos_embed": common.ParamDef((n_tok, d), scale=0.02, dtype=dt),
        "final_ln/scale": common.ParamDef((d,), "ones", dtype=dt),
        "final_ln/bias": common.ParamDef((d,), "zeros", dtype=dt),
        "head/w": common.ParamDef((d, cfg.n_classes), dtype=dt),
        "head/b": common.ParamDef((cfg.n_classes,), "zeros", dtype=dt),
        "layers/ln1/scale": common.ParamDef((L, d), "ones", dtype=dt),
        "layers/ln1/bias": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/ln2/scale": common.ParamDef((L, d), "ones", dtype=dt),
        "layers/ln2/bias": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/wq": common.ParamDef((L, d, d), dtype=dt),
        "layers/wk": common.ParamDef((L, d, d), dtype=dt),
        "layers/wv": common.ParamDef((L, d, d), dtype=dt),
        "layers/wo": common.ParamDef((L, d, d), dtype=dt),
        "layers/bq": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/bk": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/bv": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/bo": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/w_in": common.ParamDef((L, d, f), dtype=dt),
        "layers/b_in": common.ParamDef((L, f), "zeros", dtype=dt),
        "layers/w_out": common.ParamDef((L, f, d), dtype=dt),
        "layers/b_out": common.ParamDef((L, d), "zeros", dtype=dt),
    }
    return defs


def param_specs(cfg): return common.param_specs(param_defs(cfg))
def init_params(cfg, key): return common.init_params(param_defs(cfg), key)


def param_logical(cfg: ViTConfig) -> Dict[str, Tuple]:
    return {
        "patch_embed/w": (None, None, None, "tp"),
        "patch_embed/b": ("tp",),
        "cls_token": (None, None),
        "pos_embed": (None, None),
        "final_ln/scale": (None,), "final_ln/bias": (None,),
        "head/w": ("fsdp", "tp"), "head/b": ("tp",),
        "layers/ln1/scale": (None, None), "layers/ln1/bias": (None, None),
        "layers/ln2/scale": (None, None), "layers/ln2/bias": (None, None),
        "layers/wq": (None, "fsdp", "tp"),
        "layers/wk": (None, "fsdp", "tp"),
        "layers/wv": (None, "fsdp", "tp"),
        "layers/wo": (None, "tp", "fsdp"),
        "layers/bq": (None, "tp"), "layers/bk": (None, "tp"),
        "layers/bv": (None, "tp"), "layers/bo": (None, None),
        "layers/w_in": (None, "fsdp", "tp"), "layers/b_in": (None, "tp"),
        "layers/w_out": (None, "tp", "fsdp"), "layers/b_out": (None, None),
    }


def _interp_pos_embed(pos: jnp.ndarray, n_extra: int, grid_from: int,
                      grid_to: int) -> jnp.ndarray:
    """Bilinear pos-embed interpolation for resolution changes."""
    if grid_from == grid_to:
        return pos
    extra, grid = pos[:n_extra], pos[n_extra:]
    d = grid.shape[-1]
    grid = grid.reshape(grid_from, grid_from, d)
    grid = jax.image.resize(grid.astype(jnp.float32),
                            (grid_to, grid_to, d), "bilinear").astype(pos.dtype)
    return jnp.concatenate([extra, grid.reshape(grid_to * grid_to, d)], axis=0)


def forward(params: PyTree, images: jnp.ndarray, cfg: ViTConfig
            ) -> jnp.ndarray:
    """images (B, H, W, C) -> logits (B, n_classes)."""
    B, H, W, C = images.shape
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    n_extra = 1 + int(cfg.distill_token)

    x = jax.lax.conv_general_dilated(
        images.astype(_dtype(cfg)), params["patch_embed"]["w"],
        window_strides=(cfg.patch, cfg.patch), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = x + params["patch_embed"]["b"]
    gh = H // cfg.patch
    x = x.reshape(B, gh * gh, d)
    tok = jnp.broadcast_to(params["cls_token"][None], (B, n_extra, d)).astype(x.dtype)
    x = jnp.concatenate([tok, x], axis=1)
    pos = _interp_pos_embed(params["pos_embed"], n_extra,
                            cfg.img_res // cfg.patch, gh)
    x = x + pos[None]
    x = shd.hint(x, "dp", None, None)
    S = x.shape[1]

    def body(h, lp):
        y = common.layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q = (jnp.einsum("bsd,dh->bsh", y, lp["wq"]) + lp["bq"]).reshape(B, S, nh, hd)
        k = (jnp.einsum("bsd,dh->bsh", y, lp["wk"]) + lp["bk"]).reshape(B, S, nh, hd)
        v = (jnp.einsum("bsd,dh->bsh", y, lp["wv"]) + lp["bv"]).reshape(B, S, nh, hd)
        o = attn.attention(q, k, v, causal=False, impl=cfg.attn_impl,
                           q_chunk=cfg.attn_chunk)
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, d), lp["wo"]) + lp["bo"]
        y2 = common.layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"])
        z = common.gelu(jnp.einsum("bsd,df->bsf", y2, lp["w_in"]) + lp["b_in"])
        h = h + jnp.einsum("bsf,fd->bsd", z, lp["w_out"]) + lp["b_out"]
        return shd.hint(h, "dp", None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(lambda h, lp: body_fn(h, lp), x, params["layers"])
    x = common.layer_norm(x, params["final_ln"]["scale"], params["final_ln"]["bias"])
    # DeiT averages the cls and distill heads at inference; we use the mean
    # of the extra tokens as the classifier input for both variants.
    feat = jnp.mean(x[:, :n_extra], axis=1)
    logits = jnp.einsum("bd,dc->bc", feat, params["head"]["w"],
                        preferred_element_type=jnp.float32) + \
        params["head"]["b"].astype(jnp.float32)
    return logits


def loss_fn(params, batch, cfg: ViTConfig):
    logits = forward(params, batch["images"], cfg)
    loss = common.softmax_xent(logits, batch["labels"])
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}


def make_train_step(cfg: ViTConfig, opt_cfg):
    from repro.training.optimizer import adamw_update

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        return params, opt_state, dict(metrics, **opt_metrics)

    return train_step


def serve_step(params, images, cfg: ViTConfig):
    return forward(params, images, cfg)
