"""Decoder-only transformer LM (dense / MoE, GQA, RoPE, sliding-window).

Covers four assigned architectures: kimi-k2-1t-a32b, granite-moe-3b-a800m,
starcoder2-7b, gemma3-27b.  Layers are *stacked* on a leading ``L`` axis and
executed with ``lax.scan`` (small HLO, remat-friendly, overlap-friendly).

Step functions:
* ``make_train_step``  — forward + chunked-vocab loss + AdamW.
* ``prefill``          — forward returning the filled KV cache + last logits.
* ``decode_step``      — one token against a full KV cache.
* ``decode_step_sliding`` — gemma3 path: ring-buffer window caches for local
  layers, full caches only for the 1-in-6 global layers (the sub-quadratic
  structure that makes ``long_500k`` feasible).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import common, moe

PyTree = Any
NO_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.param_dtype)


def param_defs(cfg: LMConfig) -> Dict[str, common.ParamDef]:
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    H, KV, hd, f = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    dt = _dtype(cfg)
    defs = {
        "embed": common.ParamDef((V, d), "embed", dtype=dt),
        "final_norm": common.ParamDef((d,), "zeros", dtype=dt),
        "lm_head": common.ParamDef((d, V), dtype=dt),
        "layers/ln1": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/ln2": common.ParamDef((L, d), "zeros", dtype=dt),
        "layers/wq": common.ParamDef((L, d, H * hd), dtype=dt),
        "layers/wk": common.ParamDef((L, d, KV * hd), dtype=dt),
        "layers/wv": common.ParamDef((L, d, KV * hd), dtype=dt),
        "layers/wo": common.ParamDef((L, H * hd, d), dtype=dt),
    }
    if cfg.moe:
        E = cfg.n_experts_eff
        defs.update({
            "layers/router": common.ParamDef((L, d, E), dtype=jnp.float32),
            "layers/we_gate": common.ParamDef((L, E, d, f), dtype=dt),
            "layers/we_up": common.ParamDef((L, E, d, f), dtype=dt),
            "layers/we_down": common.ParamDef((L, E, f, d), dtype=dt),
        })
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            defs.update({
                "layers/ws_gate": common.ParamDef((L, d, fs), dtype=dt),
                "layers/ws_up": common.ParamDef((L, d, fs), dtype=dt),
                "layers/ws_down": common.ParamDef((L, fs, d), dtype=dt),
            })
    else:
        defs["layers/w_gate"] = common.ParamDef((L, d, f), dtype=dt)
        if not cfg.mlp_gelu():
            defs["layers/w_up"] = common.ParamDef((L, d, f), dtype=dt)
        defs["layers/w_down"] = common.ParamDef((L, f, d), dtype=dt)
    return defs


def param_specs(cfg: LMConfig) -> PyTree:
    return common.param_specs(param_defs(cfg))


def init_params(cfg: LMConfig, key: jax.Array) -> PyTree:
    return common.init_params(param_defs(cfg), key)


def param_logical(cfg: LMConfig) -> Dict[str, Tuple]:
    """Logical sharding axes aligned with ``param_defs`` paths."""
    log = {
        "embed": ("tp", "fsdp"),
        "final_norm": (None,),
        "lm_head": ("fsdp", "tp"),
        "layers/ln1": (None, None),
        "layers/ln2": (None, None),
        "layers/wq": (None, "fsdp", "tp"),
        # kv projections shard over tp only when n_kv_heads divides the tp
        # size (the 'tp_kv' rule, installed per mesh) — sub-head sharding
        # makes GSPMD partial-sum every attention score tensor (§Perf A4)
        "layers/wk": (None, "fsdp", "tp_kv"),
        "layers/wv": (None, "fsdp", "tp_kv"),
        "layers/wo": (None, "tp", "fsdp"),
    }
    if cfg.moe:
        if cfg.moe_shard_mode() == "expert":
            log.update({
                "layers/router": (None, "fsdp", None),
                "layers/we_gate": (None, "tp", "fsdp", None),
                "layers/we_up": (None, "tp", "fsdp", None),
                "layers/we_down": (None, "tp", None, "fsdp"),
            })
        else:   # shard each expert's hidden dim instead (E not divisible)
            log.update({
                "layers/router": (None, "fsdp", None),
                "layers/we_gate": (None, None, "fsdp", "tp"),
                "layers/we_up": (None, None, "fsdp", "tp"),
                "layers/we_down": (None, None, "tp", "fsdp"),
            })
        if cfg.n_shared_experts:
            log.update({
                "layers/ws_gate": (None, "fsdp", "tp"),
                "layers/ws_up": (None, "fsdp", "tp"),
                "layers/ws_down": (None, "tp", "fsdp"),
            })
    else:
        log["layers/w_gate"] = (None, "fsdp", "tp")
        if not cfg.mlp_gelu():
            log["layers/w_up"] = (None, "fsdp", "tp")
        log["layers/w_down"] = (None, "tp", "fsdp")
    return log


def _layer_windows(cfg: LMConfig) -> jnp.ndarray:
    """Per-layer attention window (NO_WINDOW = full causal)."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.sliding_window is None:
        return jnp.full((cfg.n_layers,), NO_WINDOW, jnp.int32)
    if cfg.global_every > 0:
        is_global = (idx + 1) % cfg.global_every == 0
        return jnp.where(is_global, NO_WINDOW, cfg.sliding_window).astype(jnp.int32)
    return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)


def layer_is_global(cfg: LMConfig) -> jnp.ndarray:
    idx = jnp.arange(cfg.n_layers)
    if cfg.sliding_window is None:
        return jnp.ones((cfg.n_layers,), bool)
    return (idx + 1) % max(1, cfg.global_every) == 0 if cfg.global_every else \
        jnp.zeros((cfg.n_layers,), bool)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _qkv(x, lp, cfg: LMConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, lp["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, lp["wk"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, lp["wv"]).reshape(B, S, KV, hd)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(x2, lp, cfg: LMConfig):
    """Returns (out, aux_loss). x2: (B, S, d)."""
    B, S, d = x2.shape
    if not cfg.moe:
        g = jnp.einsum("bsd,df->bsf", x2, lp["w_gate"])
        if cfg.mlp_gelu():
            h = common.gelu(g)
        else:
            u = jnp.einsum("bsd,df->bsf", x2, lp["w_up"])
            h = common.swiglu(g, u)
        out = jnp.einsum("bsf,fd->bsd", h, lp["w_down"])
        return out, jnp.zeros((), jnp.float32)
    flat = x2.reshape(B * S, d)
    mesh = shd.active_mesh()
    if cfg.moe_impl == "shard_map" and mesh is not None:
        rules = shd.get_rules()
        dp = rules.get("dp")
        dp_axes = (dp,) if isinstance(dp, str) else dp
        out, aux = moe.moe_ffn_sharded(
            flat, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            mesh=mesh, dp_axes=dp_axes, model_axis=rules.get("tp", "model"),
            fsdp_axes=rules.get("fsdp"),
            expert_sharded=cfg.moe_shard_mode() == "expert",
            n_real=cfg.n_experts)
    else:
        out, aux = moe.moe_ffn(flat, lp["router"], lp["we_gate"],
                               lp["we_up"], lp["we_down"], top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               n_real=cfg.n_experts)
    if cfg.n_shared_experts:
        g = jnp.einsum("td,df->tf", flat, lp["ws_gate"])
        u = jnp.einsum("td,df->tf", flat, lp["ws_up"])
        out = out + jnp.einsum("tf,fd->td", common.swiglu(g, u), lp["ws_down"])
    return out.reshape(B, S, d), aux


def _block(h, lp, window, cfg: LMConfig, positions, q_offset=0,
           kv_override=None):
    """One transformer layer. Returns (h, aux, (k, v))."""
    B, S, d = h.shape
    x = common.rms_norm(h, lp["ln1"])
    q, k, v = _qkv(x, lp, cfg, positions)
    if kv_override is not None:
        k, v = kv_override
    o = attn.attention(q, k, v, causal=True, window=window,
                       impl=cfg.attn_impl, q_chunk=cfg.attn_chunk,
                       q_offset=q_offset)
    h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), lp["wo"])
    h = shd.hint(h, "dp", None, None)
    x2 = common.rms_norm(h, lp["ln2"])
    f, aux = _ffn(x2, lp, cfg)
    h = shd.hint(h + f, "dp", None, None)
    return h, aux, (k, v)


# ---------------------------------------------------------------------------
# Forward / loss / train step
# ---------------------------------------------------------------------------
def hidden_states(params: PyTree, tokens: jnp.ndarray, cfg: LMConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, S) tokens -> ((B, S, d) hidden, scalar aux loss)."""
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    h = shd.hint(h, "dp", None, None)
    positions = jnp.arange(S)
    windows = _layer_windows(cfg)

    def body(carry, xs):
        h, aux = carry
        lp, window = xs
        h, a, _ = _block(h, lp, window, cfg, positions)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                               (params["layers"], windows))
    h = common.rms_norm(h, params["final_norm"])
    return h, aux


def logits_fn(params: PyTree, tokens: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    h, _ = hidden_states(params, tokens, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits


def chunked_lm_loss(h: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray,
                    chunk: int = 512) -> jnp.ndarray:
    """Mean xent without materializing (B, S, V): scan over S-chunks."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk
    hs = jnp.moveaxis(h[:, :n * chunk].reshape(B, n, chunk, d), 1, 0)
    ys = jnp.moveaxis(labels[:, :n * chunk].reshape(B, n, chunk), 1, 0)

    def body(tot, xs):
        hc, yc = xs
        logits = jnp.einsum("bcd,dv->bcv", hc, head,
                            preferred_element_type=jnp.float32)
        logits = shd.hint(logits, "dp", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    body_fn = jax.checkpoint(body)
    tot, _ = jax.lax.scan(body_fn, jnp.zeros((), jnp.float32), (hs, ys))
    if rem:
        logits = jnp.einsum("bcd,dv->bcv", h[:, n * chunk:], head,
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, n * chunk:][..., None],
                                   axis=-1)[..., 0]
        tot = tot + jnp.sum(logz - gold)
    return tot / (B * S)


def loss_fn(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: LMConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    h, aux = hidden_states(params, batch["tokens"], cfg)
    loss = chunked_lm_loss(h, params["lm_head"], batch["labels"])
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


def make_train_step(cfg: LMConfig, opt_cfg):
    from repro.training.optimizer import adamw_update

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------
def cache_specs(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    dt = _dtype(cfg)
    shape = (L, batch, max_len, KV, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dt),
        "v": jax.ShapeDtypeStruct(shape, dt),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical() -> Dict[str, Tuple]:
    # 'cache_seq'/'cache_kv' are installed per (mesh, config): KV-head
    # sharding when n_kv_heads divides the model axis (in-place DUS stays
    # local), else sequence sharding (flash-decoding style, at the cost of
    # GSPMD copying the shard at the dynamic update; §Perf B2).
    return {"k": (None, "dp", "cache_seq", "cache_kv", None),
            "v": (None, "dp", "cache_seq", "cache_kv", None),
            "length": ()}


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    s = cache_specs(cfg, batch, max_len)
    return {"k": jnp.zeros(s["k"].shape, s["k"].dtype),
            "v": jnp.zeros(s["v"].shape, s["v"].dtype),
            "length": jnp.zeros((), jnp.int32)}


def prefill(params: PyTree, tokens: jnp.ndarray, cfg: LMConfig,
            max_len: Optional[int] = None
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Forward pass that also returns the KV cache (padded to max_len)."""
    B, S = tokens.shape
    max_len = max_len or S
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    h = shd.hint(h, "dp", None, None)
    positions = jnp.arange(S)
    windows = _layer_windows(cfg)

    def body(h, xs):
        lp, window = xs
        h, _, (k, v) = _block(h, lp, window, cfg, positions)
        return h, (k, v)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, (ks, vs) = jax.lax.scan(body_fn, h, (params["layers"], windows))
    h = common.rms_norm(h, params["final_norm"])
    last = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                      preferred_element_type=jnp.float32)
    pad = max_len - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": shd.hint(ks, None, "dp", "cache_seq", "cache_kv", None),
             "v": shd.hint(vs, None, "dp", "cache_seq", "cache_kv", None),
             "length": jnp.asarray(S, jnp.int32)}
    return last, cache


def decode_step(params: PyTree, cache: Dict[str, Any], tokens: jnp.ndarray,
                cfg: LMConfig) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step: tokens (B,) int32 -> (logits (B, V) f32, new cache)."""
    B = tokens.shape[0]
    pos = cache["length"]
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(_dtype(cfg))
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    positions = jnp.full((B, 1), pos, jnp.int32)
    windows = _layer_windows(cfg)

    def body(h, xs):
        lp, window, k_l, v_l = xs
        x = common.rms_norm(h, lp["ln1"])
        q, k_new, v_new = _qkv(x, lp, cfg, positions)
        k_l = jax.lax.dynamic_update_slice(k_l, k_new, (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v_new, (0, pos, 0, 0))
        o = attn.attention_decode(q, k_l, v_l, pos + 1, window=window)
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), lp["wo"])
        x2 = common.rms_norm(h, lp["ln2"])
        f, _ = _ffn(x2, lp, cfg)
        return h + f, (k_l, v_l)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["layers"], windows, cache["k"], cache["v"]))
    h = common.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_cache = {"k": shd.hint(ks, None, "dp", "cache_seq", "cache_kv", None),
                 "v": shd.hint(vs, None, "dp", "cache_seq", "cache_kv", None),
                 "length": pos + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Sliding-window decode (gemma3): ring-buffer caches for local layers
# ---------------------------------------------------------------------------
def sliding_cache_specs(cfg: LMConfig, batch: int, max_len: int
                        ) -> Dict[str, Any]:
    assert cfg.sliding_window and cfg.global_every
    W = cfg.sliding_window
    KV, hd = cfg.n_kv_heads, cfg.hd
    n_global = cfg.n_layers // cfg.global_every
    n_local = cfg.n_layers - n_global
    dt = _dtype(cfg)
    return {
        "k_global": jax.ShapeDtypeStruct((n_global, batch, max_len, KV, hd), dt),
        "v_global": jax.ShapeDtypeStruct((n_global, batch, max_len, KV, hd), dt),
        "k_local": jax.ShapeDtypeStruct((n_local, batch, W, KV, hd), dt),
        "v_local": jax.ShapeDtypeStruct((n_local, batch, W, KV, hd), dt),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def sliding_cache_logical() -> Dict[str, Tuple]:
    return {"k_global": (None, "dp", "cache_seq", "cache_kv", None),
            "v_global": (None, "dp", "cache_seq", "cache_kv", None),
            "k_local": (None, "dp", None, "cache_kv", None),
            "v_local": (None, "dp", None, "cache_kv", None),
            "length": ()}


def init_sliding_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return {k: (jnp.zeros(s.shape, s.dtype) if k != "length"
                else jnp.zeros((), jnp.int32))
            for k, s in sliding_cache_specs(cfg, batch, max_len).items()}


def decode_step_sliding(params: PyTree, cache: Dict[str, Any],
                        tokens: jnp.ndarray, cfg: LMConfig
                        ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """gemma3 long-context decode: local layers touch only their W-token ring
    buffers, so per-step compute/memory is O(n_global·S + n_local·W)."""
    assert cfg.sliding_window and cfg.global_every
    B = tokens.shape[0]
    W = cfg.sliding_window
    g = cfg.global_every
    pos = cache["length"]
    ring = jnp.mod(pos, W)
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(_dtype(cfg))
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    positions = jnp.full((B, 1), pos, jnp.int32)

    # split stacked layer params into local / global stacks (static indices)
    import numpy as np
    idx = np.arange(cfg.n_layers)
    glb = (idx + 1) % g == 0
    loc_idx, glb_idx = idx[~glb], idx[glb]
    p_loc = jax.tree_util.tree_map(lambda x: x[loc_idx], params["layers"])
    p_glb = jax.tree_util.tree_map(lambda x: x[glb_idx], params["layers"])

    def local_body(h, xs):
        lp, k_l, v_l = xs
        x = common.rms_norm(h, lp["ln1"])
        q, k_new, v_new = _qkv(x, lp, cfg, positions)
        k_l = jax.lax.dynamic_update_slice(k_l, k_new, (0, ring, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v_new, (0, ring, 0, 0))
        # ring buffer: all slots < min(pos+1, W) are valid; relative order is
        # irrelevant to softmax.
        n_valid = jnp.minimum(pos + 1, W)
        o = attn.attention_decode(q, k_l, v_l, n_valid, window=None)
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), lp["wo"])
        x2 = common.rms_norm(h, lp["ln2"])
        f, _ = _ffn(x2, lp, cfg)
        return h + f, (k_l, v_l)

    def global_body(h, xs):
        lp, k_l, v_l = xs
        x = common.rms_norm(h, lp["ln1"])
        q, k_new, v_new = _qkv(x, lp, cfg, positions)
        k_l = jax.lax.dynamic_update_slice(k_l, k_new, (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v_new, (0, pos, 0, 0))
        o = attn.attention_decode(q, k_l, v_l, pos + 1, window=None)
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), lp["wo"])
        x2 = common.rms_norm(h, lp["ln2"])
        f, _ = _ffn(x2, lp, cfg)
        return h + f, (k_l, v_l)

    # Layer order: (g-1 locals, 1 global) repeated, then trailing locals.
    # Cache stacks are updated IN PLACE via indexed dynamic-update-slice
    # (donated buffers) — rebuilding them with concatenate copies the whole
    # multi-GB cache every decode step (§Perf B3).
    n_global = len(glb_idx)
    lead = g - 1
    k_loc_all, v_loc_all = cache["k_local"], cache["v_local"]
    k_glb_all, v_glb_all = cache["k_global"], cache["v_global"]

    def run_locals(h, k_all, v_all, lo, hi):
        if hi <= lo:
            return h, k_all, v_all
        sl = slice(lo, hi)
        pl = jax.tree_util.tree_map(lambda x: x[sl], p_loc)
        h, (ks, vs) = jax.lax.scan(local_body, h, (pl, k_all[sl], v_all[sl]))
        k_all = jax.lax.dynamic_update_slice_in_dim(k_all, ks, lo, 0)
        v_all = jax.lax.dynamic_update_slice_in_dim(v_all, vs, lo, 0)
        return h, k_all, v_all

    li = 0
    for gi in range(n_global):
        h, k_loc_all, v_loc_all = run_locals(h, k_loc_all, v_loc_all,
                                             li, li + lead)
        li += lead
        pg = jax.tree_util.tree_map(lambda x: x[gi], p_glb)
        h, (kg, vg) = global_body(
            h, (pg, k_glb_all[gi], v_glb_all[gi]))
        k_glb_all = jax.lax.dynamic_update_slice_in_dim(k_glb_all, kg[None],
                                                        gi, 0)
        v_glb_all = jax.lax.dynamic_update_slice_in_dim(v_glb_all, vg[None],
                                                        gi, 0)
    h, k_loc_all, v_loc_all = run_locals(h, k_loc_all, v_loc_all,
                                         li, len(loc_idx))

    h = common.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_cache = {
        "k_local": k_loc_all,
        "v_local": v_loc_all,
        "k_global": k_glb_all,
        "v_global": v_glb_all,
        "length": pos + 1,
    }
    return logits, new_cache
