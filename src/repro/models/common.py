"""Shared model-building utilities (pure JAX, framework-free).

Parameters are nested dicts of ``jnp`` arrays.  Every model module defines a
``param_defs(cfg) -> dict[path, ParamDef]`` table from which both
``param_specs`` (ShapeDtypeStructs for the allocation-free dry-run) and
``init_params`` (real arrays for smoke tests / training) are derived — the
two can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    init: str = "normal"        # normal | zeros | ones | embed | head
    scale: float = 1.0
    dtype: Any = jnp.bfloat16


def param_specs(defs: Dict[str, ParamDef]) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct tree — no device allocation (dry-run input)."""
    out: Dict[str, Any] = {}
    for path, d in defs.items():
        _assign(out, path, jax.ShapeDtypeStruct(d.shape, d.dtype))
    return out


def init_params(defs: Dict[str, ParamDef], key: jax.Array) -> PyTree:
    out: Dict[str, Any] = {}
    keys = jax.random.split(key, len(defs))
    for (path, d), k in zip(sorted(defs.items()), keys):
        if d.init == "zeros":
            val = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            val = jnp.ones(d.shape, d.dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(1, fan_in))
            val = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(d.dtype)
        _assign(out, path, val)
    return out


def _assign(tree: Dict[str, Any], path: str, val: Any) -> None:
    parts = path.split("/")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = val


# ---------------------------------------------------------------------------
# Normalization / activations (jnp reference; Pallas kernels in repro.kernels)
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def group_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               groups: int = 32, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm over the channel (last) axis of NHWC tensors."""
    dtype = x.dtype
    b, h, w, c = x.shape
    x32 = x.astype(jnp.float32).reshape(b, h, w, groups, c // groups)
    mu = jnp.mean(x32, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(x32, axis=(1, 2, 4), keepdims=True)
    y = ((x32 - mu) * jax.lax.rsqrt(var + eps)).reshape(b, h, w, c)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Losses / embeddings
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; logits (..., V) fp32-softmaxed, labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def timestep_embedding(t: jnp.ndarray, dim: int, max_period: float = 10_000.0
                       ) -> jnp.ndarray:
    """Sinusoidal diffusion timestep embedding, (B,) -> (B, dim), fp32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def count_params(tree: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def check_finite(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.all(jnp.isfinite(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.all(jnp.stack(leaves))
