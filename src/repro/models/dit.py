"""DiT-XL/2 (Peebles & Xie, arXiv:2212.09748): latent diffusion transformer.

adaLN-Zero conditioning on (timestep, class); patch-2 tokenization of the f8
VAE latent.  ``train_step`` implements the epsilon-prediction DDPM loss;
``serve_step`` is ONE denoising step — a k-step sampler runs it k times
(the serving engine owns the loop; roofline rows scale by ``steps``).

The VAE is a frontend stub per the assignment: inputs are latents.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiTConfig
from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import common

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def param_defs(cfg: DiTConfig) -> Dict[str, common.ParamDef]:
    L, d = cfg.n_layers, cfg.d_model
    f = cfg.d_ff
    p, c = cfg.patch, cfg.latent_channels
    dt = _dtype(cfg)
    n_tok = cfg.n_tokens()
    return {
        "patch_embed/w": common.ParamDef((p, p, c, d), dtype=dt),
        "patch_embed/b": common.ParamDef((d,), "zeros", dtype=dt),
        "pos_embed": common.ParamDef((n_tok, d), scale=0.02, dtype=dt),
        "t_mlp/w1": common.ParamDef((256, d), dtype=dt),
        "t_mlp/b1": common.ParamDef((d,), "zeros", dtype=dt),
        "t_mlp/w2": common.ParamDef((d, d), dtype=dt),
        "t_mlp/b2": common.ParamDef((d,), "zeros", dtype=dt),
        "y_embed": common.ParamDef((cfg.n_classes + 1, d), "embed", dtype=dt),
        "layers/adaln": common.ParamDef((L, d, 6 * d), "zeros", dtype=dt),
        "layers/adaln_b": common.ParamDef((L, 6 * d), "zeros", dtype=dt),
        "layers/wq": common.ParamDef((L, d, d), dtype=dt),
        "layers/wk": common.ParamDef((L, d, d), dtype=dt),
        "layers/wv": common.ParamDef((L, d, d), dtype=dt),
        "layers/wo": common.ParamDef((L, d, d), dtype=dt),
        "layers/w_in": common.ParamDef((L, d, f), dtype=dt),
        "layers/b_in": common.ParamDef((L, f), "zeros", dtype=dt),
        "layers/w_out": common.ParamDef((L, f, d), dtype=dt),
        "layers/b_out": common.ParamDef((L, d), "zeros", dtype=dt),
        "final/adaln": common.ParamDef((d, 2 * d), "zeros", dtype=dt),
        "final/adaln_b": common.ParamDef((2 * d,), "zeros", dtype=dt),
        "final/w": common.ParamDef((d, p * p * 2 * c), "zeros", dtype=dt),
        "final/b": common.ParamDef((p * p * 2 * c,), "zeros", dtype=dt),
    }


def param_specs(cfg): return common.param_specs(param_defs(cfg))
def init_params(cfg, key): return common.init_params(param_defs(cfg), key)


def param_logical(cfg: DiTConfig) -> Dict[str, Tuple]:
    log = {}
    for path, d in param_defs(cfg).items():
        if path.startswith("layers/"):
            if path.endswith(("_b", "b_in", "b_out")):
                log[path] = (None, "tp") if path.endswith(("adaln_b", "b_in")) else (None, None)
            elif path == "layers/wo" or path == "layers/w_out":
                log[path] = (None, "tp", "fsdp")
            else:
                log[path] = (None, "fsdp", "tp")
        elif len(d.shape) == 2:
            log[path] = ("fsdp", "tp") if d.shape[0] >= 256 else (None, None)
        else:
            log[path] = tuple(None for _ in d.shape)
    return log


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


def _ln(x):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def forward(params: PyTree, latents: jnp.ndarray, t: jnp.ndarray,
            y: jnp.ndarray, cfg: DiTConfig) -> jnp.ndarray:
    """latents (B, H, W, C), t (B,), y (B,) -> epsilon+sigma (B, H, W, 2C)."""
    B, Hh, Ww, C = latents.shape
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    p = cfg.patch
    gh = Hh // p

    x = jax.lax.conv_general_dilated(
        latents.astype(_dtype(cfg)), params["patch_embed"]["w"],
        window_strides=(p, p), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = (x + params["patch_embed"]["b"]).reshape(B, gh * gh, d)
    npos = params["pos_embed"].shape[0]
    if gh * gh == npos:
        pos = params["pos_embed"]
    else:   # interpolate for other resolutions (gen_1024 etc.)
        g0 = int(npos ** 0.5)
        pos = params["pos_embed"].reshape(g0, g0, d)
        pos = jax.image.resize(pos.astype(jnp.float32), (gh, gh, d),
                               "bilinear").astype(x.dtype).reshape(gh * gh, d)
    x = shd.hint(x + pos[None], "dp", None, None)

    temb = common.timestep_embedding(t, 256).astype(_dtype(cfg))
    cvec = jax.nn.silu(temb @ params["t_mlp"]["w1"] + params["t_mlp"]["b1"])
    cvec = cvec @ params["t_mlp"]["w2"] + params["t_mlp"]["b2"]
    cvec = cvec + jnp.take(params["y_embed"], y, axis=0)
    cvec = jax.nn.silu(cvec)

    S = x.shape[1]

    def body(h, lp):
        mod = jnp.einsum("bd,dk->bk", cvec, lp["adaln"]) + lp["adaln_b"]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        yx = _modulate(_ln(h), sh1, sc1)
        q = jnp.einsum("bsd,dh->bsh", yx, lp["wq"]).reshape(B, S, nh, hd)
        k = jnp.einsum("bsd,dh->bsh", yx, lp["wk"]).reshape(B, S, nh, hd)
        v = jnp.einsum("bsd,dh->bsh", yx, lp["wv"]).reshape(B, S, nh, hd)
        o = attn.attention(q, k, v, causal=False, impl=cfg.attn_impl,
                           q_chunk=cfg.attn_chunk)
        h = h + g1[:, None, :] * jnp.einsum("bsh,hd->bsd",
                                            o.reshape(B, S, d), lp["wo"])
        yx2 = _modulate(_ln(h), sh2, sc2)
        z = common.gelu(jnp.einsum("bsd,df->bsf", yx2, lp["w_in"]) + lp["b_in"])
        h = h + g2[:, None, :] * (jnp.einsum("bsf,fd->bsd", z, lp["w_out"])
                                  + lp["b_out"])
        return shd.hint(h, "dp", None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])

    mod = jnp.einsum("bd,dk->bk", cvec, params["final"]["adaln"]) + \
        params["final"]["adaln_b"]
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = _modulate(_ln(x), sh, sc)
    out = jnp.einsum("bsd,dk->bsk", x, params["final"]["w"]) + params["final"]["b"]
    out = out.reshape(B, gh, gh, p, p, 2 * C)
    out = out.transpose(0, 1, 3, 2, 4, 5).reshape(B, Hh, Ww, 2 * C)
    return out


def ddpm_alphas(n_steps: int = 1000):
    betas = jnp.linspace(1e-4, 0.02, n_steps, dtype=jnp.float32)
    alphas = jnp.cumprod(1.0 - betas)
    return alphas


def loss_fn(params, batch, cfg: DiTConfig):
    """Epsilon-prediction MSE; batch has latents (B,H,W,C), labels, rng."""
    lat = batch["latents"].astype(jnp.float32)
    B = lat.shape[0]
    rng = jax.random.fold_in(jax.random.PRNGKey(0), batch["step"])
    t = jax.random.randint(jax.random.fold_in(rng, 1), (B,), 0, 1000)
    eps = jax.random.normal(jax.random.fold_in(rng, 2), lat.shape, jnp.float32)
    a = ddpm_alphas()[t][:, None, None, None]
    noised = jnp.sqrt(a) * lat + jnp.sqrt(1 - a) * eps
    out = forward(params, noised, t, batch["labels"], cfg)
    pred_eps = out[..., :cfg.latent_channels].astype(jnp.float32)
    loss = jnp.mean(jnp.square(pred_eps - eps))
    return loss, {"loss": loss}


def make_train_step(cfg: DiTConfig, opt_cfg):
    from repro.training.optimizer import adamw_update

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        return params, opt_state, dict(metrics, **opt_metrics)

    return train_step


def serve_step(params, latents, t, y, cfg: DiTConfig):
    """One DDIM/DDPM denoising step's network evaluation."""
    return forward(params, latents, t, y, cfg)
