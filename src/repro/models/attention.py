"""Attention: GQA + RoPE + causal / sliding-window masks.

Three interchangeable implementations (``impl``):

* ``naive``   — materializes the (S, S) score matrix.  Fine for short
  sequences and as the numerical oracle.
* ``chunked`` — FlashAttention-style online softmax expressed in pure XLA:
  an outer ``lax.map`` over query chunks with an inner ``lax.scan`` over KV
  chunks carrying (m, l, acc).  Never materializes more than
  (q_chunk × kv_chunk) scores per program — this is what the full-scale
  dry-runs lower (Pallas lowers only on real TPU backends).
* ``pallas``  — the TPU kernel in ``repro.kernels`` (interpret-mode on CPU).

Shapes: q (B, S, H, D); k, v (B, S, KV, D) with H % KV == 0 (GQA groups).
``window``: None for full causal; an int w attends to keys in (i-w, i].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(angles)[:, :, None, :]      # (B, S, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mask helpers
# ---------------------------------------------------------------------------
def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window) -> jnp.ndarray:
    """(Q, K) additive bias; ``window`` may be a traced scalar."""
    dist = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(dist.shape, bool)
    if causal:
        ok &= dist >= 0
    if window is not None:
        ok &= dist < window
    return jnp.where(ok, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# Core implementations
# ---------------------------------------------------------------------------
def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, scale: float) -> jnp.ndarray:
    """q (B,Sq,KV,G,D), k (B,Sk,KV,D) -> scores (B,KV,G,Sq,Sk), fp32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def attention_naive(q, k, v, *, causal=True, window=None,
                    q_offset: int = 0) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)
    scores = _gqa_scores(qg, k, scale)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, D)


def attention_chunked(q, k, v, *, causal=True, window=None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax blocked attention in pure XLA (never materializes S²)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    q_pad, k_pad = nq * q_chunk - Sq, nk * kv_chunk - Sk
    qg = q.reshape(B, Sq, KV, G, D)
    if q_pad:
        qg = jnp.pad(qg, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0))) if k_pad else k
    vp = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0))) if k_pad else v
    qs = jnp.moveaxis(qg.reshape(B, nq, q_chunk, KV, G, D), 1, 0)   # (nq,B,qc,KV,G,D)
    ks = jnp.moveaxis(kp.reshape(B, nk, kv_chunk, KV, D), 1, 0)
    vs = jnp.moveaxis(vp.reshape(B, nk, kv_chunk, KV, D), 1, 0)

    def per_q_chunk(args):
        qi, q_blk = args                      # q_blk (B, qc, KV, G, D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        # jax.checkpoint on the kv step: without it the scan SAVES the
        # (qc × kc) score block of every step as a backward residual —
        # re-materializing the full S² attention matrix that blocking is
        # supposed to avoid.  With it, backward recomputes scores from the
        # (much smaller) q/k/v blocks: the flash-attention bwd trade.
        @jax.checkpoint
        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = _gqa_scores(q_blk, k_blk, scale)              # (B,KV,G,qc,kc) f32
            s = s + _mask_bias(q_pos, k_pos, causal, window)[None, None, None]
            # mask zero-padded KV tail (ragged Sk; causality does not cover
            # it for non-causal attention)
            s = jnp.where((k_pos < Sk)[None, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)                        # (B,qc,KV,G,D)

    outs = jax.lax.map(per_q_chunk, (jnp.arange(nq), qs))     # (nq,B,qc,KV,G,D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, KV, G, D)
    out = out[:, :Sq].reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def attention_decode(q, k_cache, v_cache, cache_len, *, window=None
                     ) -> jnp.ndarray:
    """Single-token decode: q (B, 1, H, D) against (B, S, KV, D) caches.

    ``cache_len`` is the number of valid cache entries (scalar or (B,)).
    Linear in S — no quadratic term — softmax in fp32.
    """
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, 1, KV, G, D)
    s = _gqa_scores(qg, k_cache, scale)[..., 0, :]            # (B,KV,G,S)
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))  # (B,S) or (1,S)
    if window is not None:
        valid &= k_pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, impl="chunked",
              q_chunk: int = 1024, q_offset: int = 0) -> jnp.ndarray:
    if impl == "naive" or q.shape[1] <= q_chunk:
        # single-chunk sequences: the naive path IS the blocked path
        return attention_naive(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl == "chunked":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk, kv_chunk=q_chunk,
                                 q_offset=q_offset)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention impl {impl!r}")
