"""ResNet-50 (bottleneck v1.5) in NHWC with GroupNorm-free BatchNorm.

Training uses batch statistics (GSPMD turns the batch-mean into a cross
``data``-axis all-reduce, i.e. sync-BN); serving uses the running averages
carried in the state.  Channels shard over ``model`` (tensor parallelism for
convolutions is a contraction over the channel axis — MXU-friendly).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ResNetConfig
from repro.distributed import sharding as shd
from repro.models import common

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _stage_plan(cfg: ResNetConfig):
    """[(n_blocks, c_in, c_mid, c_out, stride), ...] per stage."""
    w = cfg.width
    plan = []
    c_in = w
    for i, n in enumerate(cfg.depths):
        c_mid = w * (2 ** i)
        c_out = c_mid * 4
        stride = 1 if i == 0 else 2
        plan.append((n, c_in, c_mid, c_out, stride))
        c_in = c_out
    return plan


def param_defs(cfg: ResNetConfig) -> Dict[str, common.ParamDef]:
    dt = _dtype(cfg)
    c_final = cfg.width * (2 ** (len(cfg.depths) - 1)) * 4
    defs = {
        "stem/conv": common.ParamDef((7, 7, cfg.in_channels, cfg.width), dtype=dt),
        "stem/bn/scale": common.ParamDef((cfg.width,), "ones", dtype=jnp.float32),
        "stem/bn/bias": common.ParamDef((cfg.width,), "zeros", dtype=jnp.float32),
        "head/w": common.ParamDef((c_final, cfg.n_classes), dtype=dt),
        "head/b": common.ParamDef((cfg.n_classes,), "zeros", dtype=dt),
    }
    for si, (n, c_in, c_mid, c_out, stride) in enumerate(_stage_plan(cfg)):
        for bi in range(n):
            cin = c_in if bi == 0 else c_out
            base = f"stage{si}/block{bi}"
            defs[f"{base}/conv1"] = common.ParamDef((1, 1, cin, c_mid), dtype=dt)
            defs[f"{base}/conv2"] = common.ParamDef((3, 3, c_mid, c_mid), dtype=dt)
            defs[f"{base}/conv3"] = common.ParamDef((1, 1, c_mid, c_out), dtype=dt)
            for j, c in ((1, c_mid), (2, c_mid), (3, c_out)):
                defs[f"{base}/bn{j}/scale"] = common.ParamDef((c,), "ones", dtype=jnp.float32)
                defs[f"{base}/bn{j}/bias"] = common.ParamDef((c,), "zeros", dtype=jnp.float32)
            if bi == 0:
                defs[f"{base}/proj"] = common.ParamDef((1, 1, cin, c_out), dtype=dt)
                defs[f"{base}/bnp/scale"] = common.ParamDef((c_out,), "ones", dtype=jnp.float32)
                defs[f"{base}/bnp/bias"] = common.ParamDef((c_out,), "zeros", dtype=jnp.float32)
    return defs


def param_specs(cfg): return common.param_specs(param_defs(cfg))
def init_params(cfg, key): return common.init_params(param_defs(cfg), key)


def param_logical(cfg: ResNetConfig) -> Dict[str, Tuple]:
    log: Dict[str, Tuple] = {}
    for path, d in param_defs(cfg).items():
        if path.endswith(("scale", "bias")) or path == "head/b":
            log[path] = tuple(None for _ in d.shape)
        elif path == "head/w":
            log[path] = ("fsdp", "tp")
        else:   # conv kernels: shard output channels
            log[path] = tuple([None] * (len(d.shape) - 1) + ["tp"])
    return log


def _bn(x, scale, bias, eps=1e-5):
    """Batch statistics over (N, H, W) — GSPMD sync-BN across data shards."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x32, axis=(0, 1, 2), keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


def forward(params: PyTree, images: jnp.ndarray, cfg: ResNetConfig
            ) -> jnp.ndarray:
    x = images.astype(_dtype(cfg))
    x = jax.lax.conv_general_dilated(
        x, params["stem"]["conv"], window_strides=(2, 2),
        padding=((3, 3), (3, 3)), dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(_bn(x, params["stem"]["bn"]["scale"], params["stem"]["bn"]["bias"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, (n, c_in, c_mid, c_out, stride) in enumerate(_stage_plan(cfg)):
        for bi in range(n):
            bp = params[f"stage{si}"][f"block{bi}"]
            s = stride if bi == 0 else 1
            y = jax.nn.relu(_bn(_conv(x, bp["conv1"]), bp["bn1"]["scale"], bp["bn1"]["bias"]))
            y = jax.nn.relu(_bn(_conv(y, bp["conv2"], s), bp["bn2"]["scale"], bp["bn2"]["bias"]))
            y = _bn(_conv(y, bp["conv3"]), bp["bn3"]["scale"], bp["bn3"]["bias"])
            if bi == 0:
                sc = _bn(_conv(x, bp["proj"], s), bp["bnp"]["scale"], bp["bnp"]["bias"])
            else:
                sc = x
            x = jax.nn.relu(y + sc)
        x = shd.hint(x, "dp", None, None, "tp")
    feat = jnp.mean(x.astype(jnp.float32), axis=(1, 2))
    logits = feat @ params["head"]["w"].astype(jnp.float32) + \
        params["head"]["b"].astype(jnp.float32)
    return logits


def loss_fn(params, batch, cfg: ResNetConfig):
    logits = forward(params, batch["images"], cfg)
    loss = common.softmax_xent(logits, batch["labels"])
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}


def make_train_step(cfg: ResNetConfig, opt_cfg):
    from repro.training.optimizer import adamw_update

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        return params, opt_state, dict(metrics, **opt_metrics)

    return train_step


def serve_step(params, images, cfg: ResNetConfig):
    return forward(params, images, cfg)


# nested-path param defs create nested dicts; expose helper for smoke tests
def nested(params: PyTree, path: str):
    node = params
    for p in path.split("/"):
        node = node[p]
    return node
