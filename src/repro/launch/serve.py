"""Serving launcher: deadline-aware engine over N model replicas.

The paper's deployment: requests with per-resolution SLA deadlines are
admitted by the preferential queue (or FIFO for comparison), forwarded
between replicas on rejection, and executed in deadline-aware batches.

    PYTHONPATH=src python -m repro.launch.serve --arch deit-b \
        --replicas 3 --requests 60 --queue preferential
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-b")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--queue", default="preferential",
                    choices=["preferential", "fifo"])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--deadline", type=float, default=30.0)
    ap.add_argument("--inter-arrival", type=float, default=1.2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core.queues import FIFOQueue
    from repro.launch.steps import model_module
    from repro.serving.engine import (DeadlineAwareEngine, ServiceClass,
                                      ServingReplica)

    cfg = get_smoke_config(args.arch)
    if cfg.family not in ("vit", "resnet"):
        raise SystemExit("serve launcher demo supports vision archs; "
                         "see examples/ for LM decode serving")
    mod = model_module(cfg)
    params = mod.init_params(cfg, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda imgs: mod.forward(params, imgs, cfg))

    def run_batch(cls_name, payloads):
        return list(np.asarray(jnp.argmax(fwd(jnp.stack(payloads)), -1)))

    img = jnp.ones((cfg.img_res, cfg.img_res, 3), jnp.float32)
    run_batch("warmup", [img])

    cls = ServiceClass("hd", cfg.img_res, deadline=args.deadline,
                       proc_time=4.0)
    cls.batch_proc_time = {1: 4.0, 2: 4.6, 4: 5.8, 8: 8.0}
    reps = []
    for i in range(args.replicas):
        q = FIFOQueue() if args.queue == "fifo" else None
        reps.append(ServingReplica(i, run_batch, queue=q,
                                   max_batch=args.max_batch))
    eng = DeadlineAwareEngine(reps)

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(args.inter_arrival,
                                         size=args.requests))
    for i, at in enumerate(arrivals):
        eng.submit(img, cls, now=float(at), origin=i % args.replicas)
    eng.drain(float(arrivals[-1]))
    s = eng.stats()
    met_pct = 100 * s["met"] / max(1, s["met"] + s["missed"])
    print(f"{args.queue}: {met_pct:.1f}% deadlines met, "
          f"{s['forwards']} forwards, {s['forced']} forced, "
          f"{s['batches']} device batches")


if __name__ == "__main__":
    main()
