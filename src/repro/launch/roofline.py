"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in *per-chip seconds* —
equivalent to the global formulation because SPMD shards evenly:

    compute    = HLO_FLOPs(per chip)       / 197 TFLOP/s (bf16, v5e)
    memory     = HLO_bytes(per chip)       / 819 GB/s HBM
    collective = collective_bytes(per chip)/ 50 GB/s ICI link

FLOPs / HBM bytes / collective bytes come from ``launch.hlo_cost`` — a
recursive static cost model over the compiled per-device SPMD HLO that
multiplies while-loop bodies by their ``known_trip_count``
(``compiled.cost_analysis()`` counts loop bodies once, under-reporting a
61-layer scan by ~61x; the raw numbers are kept in the dry-run JSON for
reference).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e constants (assignment-provided)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-chip
    hbm_bytes: float             # per-chip
    coll_bytes: float            # per-chip
    coll_breakdown: Dict[str, float]
    model_flops: float           # napkin useful-FLOPs (global)
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — remat/redundancy overhead."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step achieves if every term
        overlaps perfectly: t_compute / max(all terms)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(cfg, shape) -> float:
    """Napkin 'useful' FLOPs for MODEL_FLOPS/HLO_FLOPs (global, per step).

    LM: 6·N_active·D train / 2·N_active·D forward (attention quadratic term
    excluded by convention — the ratio column then also exposes attention
    overhead at long context).  Vision/diffusion: 2·params·tokens-style
    estimates, 3x for training.
    """
    fam = cfg.family
    if fam == "lm":
        n = cfg.active_params()
        if shape.kind == "train":
            return 6.0 * n * shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            return 2.0 * n * shape.global_batch * shape.seq_len
        return 2.0 * n * shape.global_batch          # decode: one token each
    if fam in ("vit",):
        # 2·N·T per image forward (N = block params, T = tokens at this res)
        toks = cfg.n_tokens(shape.img_res or cfg.img_res)
        mult = 6.0 if shape.kind == "train" else 2.0
        return mult * cfg.total_params() * shape.global_batch * toks
    if fam == "resnet":
        base = 8.2e9 * (max(shape.img_res, 1) / 224.0) ** 2   # fwd FLOPs/image
        mult = 3.0 if shape.kind == "train" else 1.0
        return mult * base * shape.global_batch
    if fam == "dit":
        toks = cfg.n_tokens(shape.img_res)
        mult = 6.0 if shape.kind == "train" else 2.0
        return mult * cfg.total_params() * shape.global_batch * toks
    # unet
    lr = (shape.img_res // 8) if shape.img_res else cfg.latent_res
    base = 680e9 * (lr / 64.0) ** 2                  # fwd/image at latent 64
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * base * shape.global_batch


def analyze(compiled, cfg, shape, chips: int) -> RooflineTerms:
    """Roofline terms from the compiled per-device SPMD module.

    Uses launch.hlo_cost (recursive HLO cost model with loop trip-count
    multiplication) — ``compiled.cost_analysis()`` counts while bodies once
    and under-reports scanned transformers by ~n_layers x.  The raw
    cost_analysis numbers are preserved by the caller for reference.
    """
    from repro.launch.hlo_cost import HloCostModel
    model = HloCostModel(compiled.as_text())
    costs = model.entry_costs()
    return RooflineTerms(
        flops=costs.flops, hbm_bytes=costs.bytes,
        coll_bytes=costs.coll_bytes,
        coll_breakdown={k: float(v) for k, v in costs.coll_by_kind.items()},
        model_flops=model_flops_estimate(cfg, shape), chips=chips)
