"""Production mesh construction + logical-rule installation.

The mesh is built by a FUNCTION (not a module-level constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import AxisType, Mesh

from repro.distributed import sharding as shd


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / local drivers)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def install_rules(mesh: Mesh, cfg, global_batch: int,
                  kind: str = "train") -> dict:
    """Install logical→physical axis rules for one (mesh, config, shape).

    * dp   — batch dims: widest divisible data-parallel combination
    * fsdp — ZeRO weight sharding: 'data' (+ 'pod' for configs flagged
             zero_over_pods, e.g. the 1T MoE whose optimizer state cannot
             fit HBM otherwise)
    * tp   — tensor/expert parallel dims: 'model'
    * seq  — decode KV-cache sequence dim: 'model' (+ 'data' when the batch
             cannot use it, e.g. batch-1 long-context decode)
    """
    axes = set(mesh.axis_names)
    dp_spec = shd.batch_spec(mesh, global_batch)
    dp = dp_spec[0] if len(dp_spec) else None

    fsdp = "data"
    if getattr(cfg, "zero_over_pods", False) and "pod" in axes:
        fsdp = ("data", "pod")

    seq = "model"
    if dp is None and "data" in axes:
        seq = ("model", "data")

    tp_kv = None
    kv = getattr(cfg, "n_kv_heads", 0)
    if kv and kv % mesh.shape["model"] == 0:
        tp_kv = "model"

    tp = "model"
    if kind == "decode" and not getattr(cfg, "moe", False):
        # decode reads every weight once per token: keep weights fully
        # sharded over BOTH axes and skip per-step ZeRO regathers
        # (EXPERIMENTS.md §Perf iteration B1); MoE expert dims do not
        # divide model x data, so MoE archs keep the train layout.
        fsdp = None
        tp = tuple(a for a in ("model", "data") if a in axes)

    # decode-cache layout: KV-head sharding keeps the per-token cache update
    # local (in-place DUS); sequence sharding is the fallback when KV heads
    # do not divide the model axis.
    cache_kv, cache_seq = (tp_kv, None) if tp_kv else (None, seq)

    # spatial parallelism: when the batch cannot use the data axis (hi-res
    # diffusion at batch 4), shard the image/latent height instead (GSPMD
    # spatial conv partitioning with halo exchange) — §Perf iteration D.
    sp = "data" if dp is None else None

    rules = dict(dp=dp, fsdp=fsdp, tp=tp, seq=seq, tp_kv=tp_kv,
                 cache_kv=cache_kv, cache_seq=cache_seq, sp=sp)
    shd.set_rules(mesh=mesh, **rules)
    return rules
