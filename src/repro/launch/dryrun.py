import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against the production mesh, with NO device allocation
(ShapeDtypeStruct inputs), and record memory/cost/roofline terms.

The two lines above MUST precede every other import — jax locks the device
count at first initialization.

Usage:
    python -m repro.launch.dryrun --arch vit-l16 --shape serve_b1
    python -m repro.launch.dryrun --all --mesh single --out results/
    python -m repro.launch.dryrun --all --mesh multi
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import all_cells, get_config, shapes_for  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import install_rules, make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402


def _axis_prod(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _to_shardings(mesh, logical_tree, spec_tree):
    """Logical tuples -> NamedShardings, dropping (replicating) any axis
    whose size does not divide the corresponding dim — jit input shardings
    must divide evenly (GSPMD handles uneven shardings only on
    intermediates)."""
    def leaf(names, spec):
        if not isinstance(names, tuple):
            return NamedSharding(mesh, P())
        resolved = shd.logical(*names)
        fixed = []
        for i, axes in enumerate(resolved):
            if axes is None or i >= len(spec.shape) or \
                    spec.shape[i] % _axis_prod(mesh, axes) != 0:
                fixed.append(None)
            else:
                fixed.append(axes)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map(
        leaf, logical_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def _parse_override(val: str):
    if val in ("true", "True"):
        return True
    if val in ("false", "False"):
        return False
    if val in ("none", "None"):
        return None
    try:
        return int(val)
    except ValueError:
        pass
    try:
        return float(val)
    except ValueError:
        return val


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True, overrides: dict = None,
             rule_overrides: dict = None) -> dict:
    import dataclasses
    from repro.configs import get_config
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = build_cell(arch, shape_name, cfg=cfg)
    rules = install_rules(mesh, cell.cfg, cell.shape.global_batch,
                          kind=cell.shape.kind)
    if rule_overrides:
        rules.update(rule_overrides)
        shd.set_rules(mesh=mesh, **rules)
    in_shardings = _to_shardings(mesh, cell.arg_logical, cell.arg_specs)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=in_shardings,
                         donate_argnums=cell.donate if donate else ())
        lowered = jitted.lower(*cell.arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        terms = roofline.analyze(compiled, cell.cfg, cell.shape, chips)
        raw_cost = compiled.cost_analysis()
        if isinstance(raw_cost, list):
            raw_cost = raw_cost[0]

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "overrides": overrides or {},
        "rule_overrides": {k: list(v) if isinstance(v, tuple) else v
                           for k, v in (rule_overrides or {}).items()},
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in rules.items()},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "flops_per_chip": terms.flops,
        "hbm_bytes_per_chip": terms.hbm_bytes,
        "collective_bytes_per_chip": terms.coll_bytes,
        "collective_breakdown": terms.coll_breakdown,
        "model_flops": terms.model_flops,
        "roofline": terms.summary(),
        # raw XLA numbers for reference (while bodies counted once)
        "xla_cost_analysis": {"flops": float(raw_cost.get("flops", 0.0)),
                              "bytes": float(raw_cost.get("bytes accessed",
                                                          0.0))},
        "status": "ok",
    }
    shd.clear_rules()
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (hillclimb iterations)")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical rule override key=value, e.g. dp=data,model")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file name")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = _parse_override(v)
    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        parts = tuple(p for p in v.split(",") if p)
        rule_overrides[k] = (parts[0] if len(parts) == 1 else parts) \
            if parts else None

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells, skips = all_cells()
        for arch, shape, why in skips:
            print(f"SKIP {arch}:{shape} — {why}")
            (outdir / f"{arch}__{shape}__skip.json").write_text(
                json.dumps({"arch": arch, "shape": shape,
                            "status": "skipped", "reason": why}, indent=1))
    else:
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            if args.tag:
                tag += f"__{args.tag}"
            path = outdir / f"{tag}.json"
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("status") == "ok":
                    print(f"SKIP (cached) {tag}")
                    continue
            print(f"=== {tag} ===", flush=True)
            try:
                res = run_cell(arch, shape, multi, overrides=overrides,
                               rule_overrides=rule_overrides)
                rf = res["roofline"]
                print(f"  ok: compile={res['compile_s']}s "
                      f"bottleneck={rf['bottleneck']} "
                      f"t=(c {rf['t_compute_s']:.4f}, m {rf['t_memory_s']:.4f}, "
                      f"x {rf['t_collective_s']:.4f})s "
                      f"useful={rf['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:
                n_fail += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                print(f"  FAILED: {type(e).__name__}: {str(e)[:300]}",
                      flush=True)
            path.write_text(json.dumps(res, indent=1, default=str))
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
