"""Static cost model over compiled (SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — a scan over 61
transformer layers under-reports FLOPs by ~61x.  This parser rebuilds the
cost recursively, multiplying each loop body by its ``known_trip_count``
(emitted by XLA in ``backend_config``), so the roofline terms reflect what
the program actually executes.

Counted per op:
* flops — ``dot`` (2·|result|·K, batch dims handled by |result|),
  ``convolution`` (2·|result|·K·spatial_kernel/groups);
* hbm bytes — operands + results of every scheduled op except free ops
  (tuple/gte/parameter/constant/bitcast); dynamic-slice counts the slice,
  dynamic-update-slice counts 2x the update (in-place semantics);
* collective bytes — result bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (per-device view, since
  the module is the SPMD per-device program).

Validated against cost_analysis() on loop-free modules (tests).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+"
                    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "add-dependency", "partition-id", "replica-id",
             "iota", "while", "conditional", "call"}


def _parse_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _parse_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(shape_str: str) -> int:
    n = 0
    for _, dims in _parse_dims(shape_str):
        m = 1
        for d in dims:
            m *= d
        n += m
    return n


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Costs":
        return Costs(self.flops * m, self.bytes * m, self.coll_bytes * m,
                     {k: v * m for k, v in self.coll_by_kind.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.shapes: Dict[str, str] = {}           # op name -> result type
        self.unknown_trip_loops: List[str] = []
        self._parse(hlo_text)
        self._memo: Dict[str, Costs] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if not stripped:
                continue
            if not line.startswith(" ") and stripped.endswith("{"):
                header = stripped
                if header.startswith("ENTRY "):
                    header = header[len("ENTRY "):]
                m = _COMP_RE.match(header.lstrip("%"))
                if m:
                    current = m.group(1)
                    self.computations[current] = []
                continue
            if stripped == "}":
                current = None
                continue
            if current is None:
                # ENTRY computation ops may appear inside "ENTRY %main {"
                continue
            m = _OP_RE.match(stripped)
            if not m:
                continue
            name, rtype, opcode, rest = m.groups()
            # operands: first balanced-paren argument list
            depth, args = 1, []
            buf = ""
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args.append(buf)
                        break
                if depth >= 1 and not (ch == "(" and depth == 2 and not buf):
                    if ch == "," and depth == 1:
                        args.append(buf)
                        buf = ""
                        continue
                    buf += ch
            operands = [a.strip().lstrip("%") for a in args if a.strip()]
            op = Op(name, rtype, opcode, operands, stripped)
            self.computations[current].append(op)
            self.shapes[name] = rtype

        # ENTRY computation: HLO prints it as "ENTRY %main.123 (...) -> ... {"
        # the regex above already handles it because the line ends with "{".

    def _operand_type(self, op: Op, idx: int) -> str:
        if idx < len(op.operands):
            return self.shapes.get(op.operands[idx], "")
        return ""

    # -- per-op costs ---------------------------------------------------------
    def _dot_flops(self, op: Op) -> float:
        lhs_t = self._operand_type(op, 0)
        dims = _parse_dims(lhs_t)
        if not dims:
            return 0.0
        lhs_dims = dims[0][1]
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        k = 1
        if m:
            for i in m.group(1).split(","):
                if i:
                    k *= lhs_dims[int(i)] if int(i) < len(lhs_dims) else 1
        return 2.0 * _numel(op.result_type) * k

    def _conv_flops(self, op: Op) -> float:
        kern_t = self._operand_type(op, 1)
        dims = _parse_dims(kern_t)
        if not dims:
            return 0.0
        kern = dims[0][1]
        m = re.search(r"dim_labels=([\w?]+)_([\w?]+)->", op.line)
        # kernel layout e.g. 01io: spatial dims + i (input feat) + o
        if m:
            klabels = m.group(2)
            k_contract = 1
            for lab, size in zip(klabels, kern):
                if lab != "o":
                    k_contract *= size
        else:
            k_contract = 1
            for size in kern[:-1]:
                k_contract *= size
        g = 1
        gm = re.search(r"feature_group_count=(\d+)", op.line)
        if gm:
            g = int(gm.group(1))
        return 2.0 * _numel(op.result_type) * k_contract / max(1, g)

    _VIEW_OPS = {"bitcast", "convert", "copy", "reshape"}

    def _effective_users(self, consumers: Dict[str, List["Op"]],
                         start: str) -> List["Op"]:
        """Consumers of ``start`` looking through dtype/layout view chains
        (XLA-CPU float normalization wraps bf16 in-place updates in f32
        convert round-trips that a TPU backend would not emit)."""
        out, stack, seen = [], list(consumers.get(start, [])), set()
        while stack:
            u = stack.pop()
            if u.name in seen:
                continue
            seen.add(u.name)
            if u.opcode in self._VIEW_OPS:
                stack.extend(consumers.get(u.name, []))
            else:
                out.append(u)
        return out

    def _fusion_param_bytes(self, op: Op) -> Optional[float]:
        """Bytes actually read by a fusion: parameters consumed ONLY via
        dynamic-slice inside the fused computation are charged the slice
        size, not the full operand (the scan-over-stacked-weights pattern —
        charging the full (L, d, f) array per trip would overcount by Lx)."""
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        if not m or m.group(1) not in self.computations:
            return None
        comp_ops = self.computations[m.group(1)]
        param_name = {}                    # param index -> op name
        consumers: Dict[str, List[Op]] = {}
        producers: Dict[str, Op] = {}
        for o in comp_ops:
            producers[o.name] = o
            if o.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", o.line)
                if pm:
                    param_name[int(pm.group(1))] = o.name
            for src in o.operands:
                consumers.setdefault(src, []).append(o)

        def resolves_to(name: str, target: str) -> bool:
            while name != target:
                o = producers.get(name)
                if o is None or o.opcode not in self._VIEW_OPS or \
                        not o.operands:
                    return False
                name = o.operands[0]
            return True
        total = 0.0
        inplace_dus = False
        for i, operand in enumerate(op.operands):
            pname = param_name.get(i)
            users = self._effective_users(consumers, pname) if pname else []
            if users and all(u.opcode == "dynamic-slice" for u in users):
                total += sum(shape_bytes(u.result_type) for u in users)
            elif users and all(u.opcode == "dynamic-update-slice" and
                               u.operands and
                               resolves_to(u.operands[0], pname)
                               for u in users):
                # in-place scan-stack write: the device touches only the
                # update slice (read-modify-write), not the whole carried
                # array — charging the full array per trip would overcount
                # a 61-layer scan by 61x.  (View/convert wrappers around the
                # DUS are XLA-CPU float-normalization artifacts.)
                for u in users:
                    upd_t = self.shapes.get(u.operands[1], "") if \
                        len(u.operands) > 1 else ""
                    total += 2.0 * shape_bytes(upd_t or u.result_type)
                inplace_dus = True
            else:
                total += shape_bytes(self.shapes.get(operand, ""))
        # output side: a ROOT dynamic-update-slice writes only the update
        root = comp_ops[-1] if comp_ops else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd_t = ""
            if len(root.operands) > 1:
                upd_t = next((o.result_type for o in comp_ops
                              if o.name == root.operands[1]), "")
            total += 2.0 * shape_bytes(upd_t or root.result_type)
        elif inplace_dus:
            pass        # result aliases the in-place-updated parameter
        else:
            total += shape_bytes(op.result_type)
        return total

    def _op_costs(self, op: Op) -> Costs:
        c = Costs()
        code = op.opcode
        if code in _FREE_OPS:
            return c
        if code == "dot":
            c.flops = self._dot_flops(op)
        elif code == "convolution":
            c.flops = self._conv_flops(op)
        # bytes
        if code == "dynamic-slice":
            c.bytes = 2.0 * shape_bytes(op.result_type)
        elif code == "dynamic-update-slice":
            upd = self._operand_type(op, 1)
            c.bytes = 2.0 * shape_bytes(upd)
        elif code == "fusion":
            fb = self._fusion_param_bytes(op)
            if fb is None:
                fb = float(shape_bytes(op.result_type)
                           + sum(shape_bytes(self.shapes.get(o, ""))
                                 for o in op.operands))
            c.bytes = fb
        else:
            b = shape_bytes(op.result_type)
            for o in op.operands:
                b += shape_bytes(self.shapes.get(o, ""))
            c.bytes = float(b)
        if code in COLLECTIVES or code.replace("-start", "") in COLLECTIVES:
            kind = code.replace("-start", "")
            cb = float(shape_bytes(op.result_type))
            c.coll_bytes = cb
            c.coll_by_kind = {kind: cb}
        return c

    def _normalization_artifacts(self, name: str) -> set:
        """Ops to skip: XLA-CPU float-normalization sandwiches
        convert(bf16->f32) -> dynamic-update-slice -> convert(f32->bf16)
        around in-place cache updates.  A TPU backend updates bf16 caches
        natively; charging the two full-tensor converts would bill the
        whole multi-GB cache per decode step."""
        skip = set()
        ops = self.computations.get(name, [])
        consumers: Dict[str, List[Op]] = {}
        for o in ops:
            for src in o.operands:
                consumers.setdefault(src, []).append(o)
        for o in ops:
            if o.opcode != "convert":
                continue
            users = consumers.get(o.name, [])
            if users and all(u.opcode == "dynamic-update-slice" and
                             u.operands and u.operands[0] == o.name
                             for u in users):
                dus_users = [c for u in users
                             for c in consumers.get(u.name, [])]
                if dus_users and all(c.opcode == "convert"
                                     for c in dus_users):
                    skip.add(o.name)
                    skip.update(c.name for c in dus_users)
        return skip

    # -- recursive aggregation ------------------------------------------------
    def computation_costs(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        total = Costs()
        self._memo[name] = total      # cycles shouldn't occur; safe default
        skip = self._normalization_artifacts(name)
        for op in self.computations.get(name, []):
            if op.name in skip:
                continue
            total += self._op_costs(op)
            if op.opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                else:
                    self.unknown_trip_loops.append(op.name)
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                if body:
                    total += self.computation_costs(body).scaled(trip)
                if cond:
                    total += self.computation_costs(cond).scaled(trip + 1)
                # loop state traffic: carried tuple read+written per step
                total.bytes += 0.0
            elif op.opcode in ("fusion", "call", "custom-call", "reduce",
                               "sort", "scatter", "map", "reduce-window",
                               "select-and-scatter"):
                m = _CALLS_RE.search(op.line)
                if m and m.group(1) in self.computations and \
                        op.opcode in ("fusion", "call"):
                    sub = self.computation_costs(m.group(1))
                    # fusion bytes already counted at the call site; only
                    # flops (dots/convs inside fusions) bubble up.
                    total.flops += sub.flops
                    total.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] = \
                            total.coll_by_kind.get(k, 0.0) + v
            elif op.opcode == "conditional":
                bm = _COND_BRANCHES_RE.search(op.line)
                if bm:
                    branches = [b.strip().lstrip("%") for b in
                                bm.group(1).split(",")]
                    costs = [self.computation_costs(b) for b in branches
                             if b in self.computations]
                    if costs:
                        worst = max(costs, key=lambda c: c.flops + c.bytes)
                        total += worst
        self._memo[name] = total
        return total

    def entry_costs(self) -> Costs:
        # the entry computation is the one not called by anyone
        called = set()
        for ops in self.computations.values():
            for op in ops:
                for m in re.finditer(
                        r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)",
                        op.line):
                    called.add(m.group(1))
                bm = _COND_BRANCHES_RE.search(op.line)
                if bm:
                    for b in bm.group(1).split(","):
                        called.add(b.strip().lstrip("%"))
        roots = [c for c in self.computations if c not in called]
        total = Costs()
        # prefer a root containing 'main'; otherwise sum all roots
        mains = [r for r in roots if "main" in r]
        for r in (mains or roots):
            total += self.computation_costs(r)
        return total


def analyze_hlo(hlo_text: str) -> Costs:
    return HloCostModel(hlo_text).entry_costs()
