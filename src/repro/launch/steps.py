"""Cell builder: (architecture config × shape) -> lowered-able step function.

One place defines, for every (arch, shape) cell:
* the step callable (train / prefill / decode / serve),
* abstract argument specs (ShapeDtypeStructs — the dry-run never allocates),
* logical sharding trees for the arguments,
* a real-input factory for smoke tests and the end-to-end drivers.

Used by launch/dryrun.py, launch/train.py, launch/serve.py and the smoke
tests, so the dry-run exercises exactly the code the drivers run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, shapes_for
from repro.configs.base import (DiTConfig, LMConfig, ResNetConfig, UNetConfig,
                                ViTConfig)
from repro.configs.shapes import ShapeSpec
from repro.models import common, dit, resnet, transformer, unet, vit
from repro.training.optimizer import AdamWConfig, OptState, init_opt_state, \
    opt_state_specs

PyTree = Any


def model_module(cfg):
    return {"lm": transformer, "vit": vit, "resnet": resnet,
            "dit": dit, "unet": unet}[cfg.family]


def _nest_logical(flat: Dict[str, Tuple]) -> PyTree:
    out: Dict[str, Any] = {}
    for path, spec in flat.items():
        common._assign(out, path, tuple(spec))
    return out


def opt_cfg_for(cfg) -> AdamWConfig:
    return AdamWConfig(state_dtype=jnp.dtype(getattr(cfg, "opt_state_dtype",
                                                     "float32")))


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: Any
    step_fn: Callable
    arg_specs: Tuple             # abstract args (ShapeDtypeStructs)
    arg_logical: Tuple           # logical sharding trees aligned with args
    make_args: Callable          # key -> real args (smoke/driver use)
    donate: Tuple[int, ...] = ()

    @property
    def label(self) -> str:
        return f"{self.arch}:{self.shape.name}"


def _batch_tree_logical(tree: PyTree) -> PyTree:
    """Shard the leading dim of every array leaf over dp."""
    def leaf(x):
        nd = len(x.shape)
        return ("dp",) + (None,) * (nd - 1) if nd else ()
    return jax.tree_util.tree_map(leaf, tree)


def _opt_logical(param_logical_tree: PyTree) -> OptState:
    return OptState(step=(), m=param_logical_tree, v=param_logical_tree)


# ---------------------------------------------------------------------------
# Family-specific batch builders
# ---------------------------------------------------------------------------
def _lm_batch_specs(cfg: LMConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def _vision_batch_specs(cfg, shape: ShapeSpec):
    B, r = shape.global_batch, shape.img_res
    return {"images": jax.ShapeDtypeStruct((B, r, r, 3), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B,), jnp.int32)}


def _dit_batch_specs(cfg: DiTConfig, shape: ShapeSpec):
    B = shape.global_batch
    lr = cfg.latent_res(shape.img_res)
    return {"latents": jax.ShapeDtypeStruct((B, lr, lr, cfg.latent_channels),
                                            jnp.float32),
            "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _unet_batch_specs(cfg: UNetConfig, shape: ShapeSpec):
    B = shape.global_batch
    lr = shape.img_res // 8 if shape.img_res else cfg.latent_res
    return {"latents": jax.ShapeDtypeStruct((B, lr, lr, cfg.latent_channels),
                                            jnp.float32),
            "ctx": jax.ShapeDtypeStruct((B, cfg.ctx_len, cfg.ctx_dim),
                                        jnp.float32),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _materialize(specs: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if jnp.issubdtype(s.dtype, jnp.integer):
            # small id range: valid for every vocab / class-count in the zoo
            out.append(jnp.zeros(s.shape, s.dtype) if not s.shape else
                       jax.random.randint(k, s.shape, 0, 8).astype(s.dtype))
        else:
            out.append(jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * 0.1)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, cfg=None) -> Cell:
    cfg = cfg or get_config(arch)
    shape = shapes_for(cfg)[shape_name]
    mod = model_module(cfg)
    p_specs = mod.param_specs(cfg)
    p_logical = _nest_logical(mod.param_logical(cfg))

    if shape.kind == "train":
        ocfg = opt_cfg_for(cfg)
        step = mod.make_train_step(cfg, ocfg)
        o_specs = opt_state_specs(p_specs, ocfg)
        if cfg.family == "lm":
            b_specs = _lm_batch_specs(cfg, shape)
        elif cfg.family in ("vit", "resnet"):
            b_specs = _vision_batch_specs(cfg, shape)
        elif cfg.family == "dit":
            b_specs = _dit_batch_specs(cfg, shape)
        else:
            b_specs = _unet_batch_specs(cfg, shape)
        arg_specs = (p_specs, o_specs, b_specs)
        arg_logical = (p_logical, _opt_logical(p_logical),
                       _batch_tree_logical(b_specs))

        def make_args(key):
            params = mod.init_params(cfg, key)
            return (params, init_opt_state(params, ocfg),
                    _materialize(b_specs, jax.random.fold_in(key, 1)))

        return Cell(arch, shape, cfg, step, arg_specs, arg_logical,
                    make_args, donate=(0, 1))

    if cfg.family == "lm":
        if shape.kind == "prefill":
            def step(params, tokens):
                return transformer.prefill(params, tokens, cfg)
            t_spec = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32)
            arg_specs = (p_specs, t_spec)
            arg_logical = (p_logical, ("dp", None))

            def make_args(key):
                return (mod.init_params(cfg, key),
                        jax.random.randint(key, t_spec.shape, 0,
                                           cfg.vocab_size).astype(jnp.int32))

            return Cell(arch, shape, cfg, step, arg_specs, arg_logical, make_args)

        # decode
        B, S = shape.global_batch, shape.seq_len
        sliding = cfg.sliding_window is not None and cfg.global_every > 0
        if sliding:
            c_specs = transformer.sliding_cache_specs(cfg, B, S)
            c_logical = transformer.sliding_cache_logical()

            def step(params, cache, tokens):
                return transformer.decode_step_sliding(params, cache, tokens, cfg)

            def make_args(key):
                cache = transformer.init_sliding_cache(cfg, B, S)
                cache["length"] = jnp.asarray(S // 2, jnp.int32)
                return (mod.init_params(cfg, key), cache,
                        jax.random.randint(key, (B,), 0, cfg.vocab_size
                                           ).astype(jnp.int32))
        else:
            c_specs = transformer.cache_specs(cfg, B, S)
            c_logical = transformer.cache_logical()

            def step(params, cache, tokens):
                return transformer.decode_step(params, cache, tokens, cfg)

            def make_args(key):
                cache = transformer.init_cache(cfg, B, S)
                cache["length"] = jnp.asarray(S // 2, jnp.int32)
                return (mod.init_params(cfg, key), cache,
                        jax.random.randint(key, (B,), 0, cfg.vocab_size
                                           ).astype(jnp.int32))

        arg_specs = (p_specs, c_specs, jax.ShapeDtypeStruct((B,), jnp.int32))
        arg_logical = (p_logical, c_logical, ("dp",))
        return Cell(arch, shape, cfg, step, arg_specs, arg_logical,
                    make_args, donate=(1,))

    # vision / diffusion serve
    if cfg.family in ("vit", "resnet"):
        i_spec = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.img_res, shape.img_res, 3), jnp.float32)

        def step(params, images):
            return mod.serve_step(params, images, cfg)

        def make_args(key):
            return (mod.init_params(cfg, key),
                    jax.random.normal(key, i_spec.shape, jnp.float32))

        return Cell(arch, shape, cfg, step, (p_specs, i_spec),
                    (p_logical, ("dp", None, None, None)), make_args)

    if cfg.family == "dit":
        B = shape.global_batch
        lr = cfg.latent_res(shape.img_res)
        l_spec = jax.ShapeDtypeStruct((B, lr, lr, cfg.latent_channels),
                                      jnp.float32)

        def step(params, latents, t, y):
            return dit.serve_step(params, latents, t, y, cfg)

        def make_args(key):
            return (mod.init_params(cfg, key),
                    jax.random.normal(key, l_spec.shape, jnp.float32),
                    jnp.full((B,), 500, jnp.int32),
                    jnp.zeros((B,), jnp.int32))

        arg_specs = (p_specs, l_spec, jax.ShapeDtypeStruct((B,), jnp.int32),
                     jax.ShapeDtypeStruct((B,), jnp.int32))
        arg_logical = (p_logical, ("dp", None, None, None), ("dp",), ("dp",))
        return Cell(arch, shape, cfg, step, arg_specs, arg_logical, make_args)

    # unet serve
    B = shape.global_batch
    lr = shape.img_res // 8 if shape.img_res else cfg.latent_res
    l_spec = jax.ShapeDtypeStruct((B, lr, lr, cfg.latent_channels), jnp.float32)
    c_spec = jax.ShapeDtypeStruct((B, cfg.ctx_len, cfg.ctx_dim), jnp.float32)

    def step(params, latents, t, ctx):
        return unet.serve_step(params, latents, t, ctx, cfg)

    def make_args(key):
        return (mod.init_params(cfg, key),
                jax.random.normal(key, l_spec.shape, jnp.float32),
                jnp.full((B,), 500, jnp.int32),
                jax.random.normal(jax.random.fold_in(key, 1), c_spec.shape,
                                  jnp.float32))

    arg_specs = (p_specs, l_spec, jax.ShapeDtypeStruct((B,), jnp.int32), c_spec)
    arg_logical = (p_logical, ("dp", "sp", None, None), ("dp",),
                   ("dp", None, None))
    return Cell(arch, shape, cfg, step, arg_specs, arg_logical, make_args)
