"""Training launcher.

Local (CPU / single host) end-to-end run at smoke scale; on a pod the same
driver runs with ``--full`` after ``jax.distributed.initialize()`` (the
mesh/sharding machinery is the dry-run-proven path in launch/mesh.py).

    PYTHONPATH=src python -m repro.launch.train --arch deit-b --steps 100
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64, help="LM sequence length")
    ap.add_argument("--full", action="store_true",
                    help="full published config (pod-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.configs.shapes import ShapeSpec
    from repro.launch import steps as S
    from repro.training.train_loop import TrainLoopConfig, run

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.family == "lm":
        shape = ShapeSpec("cli", "train", seq_len=args.seq,
                          global_batch=args.batch)
    else:
        shape = ShapeSpec("cli", "train", img_res=getattr(cfg, "img_res", 64),
                          global_batch=args.batch)
    S.shapes_for(cfg)["cli"] = shape
    try:
        cell = S.build_cell(args.arch, "cli", cfg=cfg)
    finally:
        S.shapes_for(cfg).pop("cli", None)

    out = run(cell, TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, seed=args.seed))
    print(f"final loss {out['losses'][-1][1]:.4f} in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
