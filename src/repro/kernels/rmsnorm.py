"""Fused RMSNorm kernel (pl.pallas_call + BlockSpec).

One HBM round-trip instead of three (read → mean-square reduce → scale
write are fused in VMEM).  Rows are tiled (block_rows × d); the scale
vector rides in VMEM across the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_fwd(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """x (N, d), scale (d,) -> (N, d)."""
    N, d = x.shape
    block_rows = min(block_rows, N)
    n = -(-N // block_rows)
    pad = n * block_rows - N
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n * block_rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:N]
