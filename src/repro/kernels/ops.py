"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — Python
evaluation of the kernel body, used for correctness validation.  On a real
TPU backend ``interpret`` flips to False and the same BlockSpecs compile to
Mosaic.  Model code selects kernels via the config's ``attn_impl='pallas'``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import event_select as _es
from repro.kernels import flash_attention as _fa
from repro.kernels import fleet_feasibility as _ff
from repro.kernels import link_cost as _lc
from repro.kernels import moe_gemm as _mg
from repro.kernels import rmsnorm as _rn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D). GQA via head mapping."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    D_pad = (-D) % 128
    # fold batch x heads; repeat is logical only (index_map equivalent):
    # we expand KV to H by gathering, which XLA fuses into the kernel feed.
    kh = jnp.repeat(k, G, axis=2)
    vh = jnp.repeat(v, G, axis=2)
    q3 = jnp.moveaxis(q, 2, 1).reshape(B * H, S, D)
    k3 = jnp.moveaxis(kh, 2, 1).reshape(B * H, S, D)
    v3 = jnp.moveaxis(vh, 2, 1).reshape(B * H, S, D)
    if D_pad:
        q3 = _pad_to(q3, 128, 2)
        k3 = _pad_to(k3, 128, 2)
        v3 = _pad_to(v3, 128, 2)
    out = _fa.flash_attention_fwd(q3, k3, v3, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  sm_scale=D ** -0.5,
                                  interpret=_interpret())
    out = out[:, :, :D].reshape(B, H, S, D)
    return jnp.moveaxis(out, 1, 2)


@jax.jit
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """x (..., d) RMS-normalized and scaled by (1 + scale)."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = _rn.rmsnorm_fwd(flat, scale, interpret=_interpret())
    return out.reshape(shape)


@jax.jit
def moe_gemm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(E, C, d) x (E, d, f) -> (E, C, f) grouped GEMM."""
    return _mg.moe_gemm(x, w, interpret=_interpret())


@jax.jit
def fleet_feasibility(starts: jnp.ndarray, ends: jnp.ndarray,
                      sizes: jnp.ndarray, n: jnp.ndarray, ps: jnp.ndarray,
                      d: jnp.ndarray, cpu_free: jnp.ndarray, head=None):
    """Stacked (K, N) fleet ledger -> ((K,) feasible mask, (K,) load).

    The fleet simulator's cross-node admission scan fused with the
    router's pending-work reduction; see kernels/fleet_feasibility.py.
    ``head`` marks retired slots (fleetsim head-pointer rows; default 0).
    """
    with jax.named_scope("kernels.fleet_feasibility"):
        return _ff.fleet_feasibility_fwd(starts, ends, sizes, n, ps, d,
                                         cpu_free, head,
                                         interpret=_interpret())


@jax.jit
def event_select(t_a, node_a, d_a, p_a, pay_a, avail_a,
                 t_b, node_b, d_b, p_b, pay_b, avail_b,
                 starts: jnp.ndarray, ends: jnp.ndarray, sizes: jnp.ndarray,
                 n: jnp.ndarray, head, speeds: jnp.ndarray,
                 busy: jnp.ndarray, latency: jnp.ndarray,
                 inv_bw: jnp.ndarray):
    """Fused next-event merge + per-hop referral scoring (DESIGN.md §7).

    Candidate scalars ``(t, node, d, p, payload, avail)`` for the fresh
    arrival (``_a``) and the re-arrival buffer head (``_b``); stacked
    (K, N) ledger windows; full (K, K) NetParams tensors (zeros for a
    network-free run).  Returns ``(take_fresh, t, node, feasible (K,),
    arrive (K,), j (K,), cap (K,), load (K,))``; oracle:
    :func:`repro.kernels.ref.event_select_ref`.
    """
    with jax.named_scope("kernels.event_select"):
        return _es.event_select_fwd(t_a, node_a, d_a, p_a, pay_a, avail_a,
                                    t_b, node_b, d_b, p_b, pay_b, avail_b,
                                    starts, ends, sizes, n, head, speeds,
                                    busy, latency, inv_bw,
                                    interpret=_interpret())


@jax.jit
def link_cost(starts: jnp.ndarray, ends: jnp.ndarray, sizes: jnp.ndarray,
              n: jnp.ndarray, ps: jnp.ndarray, d: jnp.ndarray,
              busy: jnp.ndarray, head, t_src: jnp.ndarray,
              lat_row: jnp.ndarray, inv_bw_row: jnp.ndarray,
              payload: jnp.ndarray):
    """Fused referral scoring: transfer delay + ledger feasibility.

    One request at a source node at ``t_src`` against K candidates'
    stacked (K, N) ledgers; ``lat_row``/``inv_bw_row`` are the source's
    rows of the :class:`repro.netsim.NetParams` tensors.  Returns
    ``((K,) feasible, (K,) arrival, (K,) load)``; oracle:
    :func:`repro.kernels.ref.link_cost_ref`.
    """
    with jax.named_scope("kernels.link_cost"):
        return _lc.link_cost_fwd(starts, ends, sizes, n, ps, d, busy, head,
                                 t_src, lat_row, inv_bw_row, payload,
                                 interpret=_interpret())
