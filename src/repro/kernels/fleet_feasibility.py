"""Pallas kernel: cross-node ledger feasibility scan over a whole fleet.

The fleet simulator's hot inner step asks, for ONE request, "which of the
K nodes' admission ledgers can still fit it before its deadline?" — the
generalized :func:`repro.core.jax_queue.feasible_nodes` over stacked
``(num_nodes, capacity)`` arrays.  Per node that is two bisects plus a
prefix sum; over a fleet it is a bandwidth-bound scan of the stacked
ledger, so the kernel fuses it with the router's load reduction (pending
work per node) in one VMEM pass: each grid program loads a
``(block_nodes, capacity)`` tile of the fleet ledger once and emits both
the feasibility bit and the load for its nodes.  The two searchsorted
calls of the per-node test become masked count-reductions (the ledgers
are time-sorted, so ``searchsorted(xs, d) == sum(xs < d)``), which is
what makes the scan one vectorized pass instead of a bisect per node.

Pure-jnp oracle: :func:`repro.kernels.ref.fleet_feasibility_ref`.  On
non-TPU backends the wrapper in :mod:`repro.kernels.ops` runs this body
in interpret mode (traced once under jit, so it lowers to ordinary XLA —
the CPU fallback costs nothing at runtime).

Status: the event-time fleet scan (DESIGN.md §7) folds this scoring into
:mod:`repro.kernels.event_select`; ``fleet_feasibility`` remains the
standalone cross-node admission kernel (no event merge, no network) for
router-style batch scoring and as a parity anchor for the shared
geometry.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _fleet_feasibility_kernel(d_ref, starts_ref, ends_ref, sizes_ref, n_ref,
                              head_ref, ps_ref, free_ref, feas_ref, load_ref,
                              *, eps: float):
    d = d_ref[0, 0]
    starts = starts_ref[...]                     # (bk, N)
    ends = ends_ref[...]
    sizes = sizes_ref[...]
    n = n_ref[...]                               # (bk, 1) int32
    head = head_ref[...]                         # (bk, 1) int32
    ps = ps_ref[...]                             # (bk, 1)
    free = free_ref[...]                         # (bk, 1)
    bk, N = starts.shape
    tail = head + n
    idx = jax.lax.broadcasted_iota(jnp.int32, (bk, N), 1)

    # searchsorted on a sorted ledger == masked count (one vector reduce).
    # Retired slots [0, head) hold -BIG/0 and count into both sums
    # identically, so the straddle comparison stays consistent.
    cap_idx = jnp.sum((starts < d).astype(jnp.int32), axis=1, keepdims=True)
    e_hi = jnp.sum((ends < d).astype(jnp.int32), axis=1, keepdims=True)

    # interior gaps: live position i has a gap iff starts[i] > ends[i-1]
    prev_ends = jnp.concatenate(
        [jnp.full((bk, 1), -BIG, ends.dtype), ends[:, :-1]], axis=1)
    has_gap = (starts > prev_ends) & (idx >= head + 1) & (idx < tail)
    gap_ok = has_gap & (idx <= e_hi)
    prev_gap = jnp.max(jnp.where(gap_ok, idx, head), axis=1, keepdims=True)

    no_straddle = e_hi >= cap_idx
    j = jnp.where(no_straddle, e_hi, prev_gap)
    j_clip = jnp.minimum(j, N - 1)
    start_j = jnp.sum(jnp.where(idx == j_clip, starts, 0.0), axis=1,
                      keepdims=True)
    start_j = jnp.where(j < tail, start_j, BIG)
    cap = jnp.where(no_straddle, d, jnp.minimum(start_j, d))
    # j == head straddle fallback: front window
    start_h = jnp.sum(jnp.where(idx == jnp.minimum(head, N - 1), starts, 0.0),
                      axis=1, keepdims=True)
    start_h = jnp.where(n > 0, start_h, BIG)
    front = (~no_straddle) & (prev_gap == head)
    cap = jnp.where(front, jnp.minimum(start_h, d), cap)
    j = jnp.where(front, head, j)

    pw_j = jnp.sum(jnp.where(idx < j, sizes, 0.0), axis=1, keepdims=True)
    feasible = (cap - (free + pw_j) >= ps - eps) & (cap > free) & (tail < N)
    feas_ref[...] = feasible.astype(jnp.int32)
    load_ref[...] = jnp.sum(sizes, axis=1, keepdims=True)


def fleet_feasibility_fwd(starts: jnp.ndarray, ends: jnp.ndarray,
                          sizes: jnp.ndarray, n: jnp.ndarray,
                          ps: jnp.ndarray, d: jnp.ndarray,
                          cpu_free: jnp.ndarray, head=None, *,
                          eps: float = 1e-6, block_nodes: int = 8,
                          interpret: bool = True
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stacked (K, N) ledger arrays -> ((K,) feasible bool, (K,) load).

    ``ps`` is the request's per-node speed-scaled processing time, ``d`` its
    absolute deadline (scalar), ``cpu_free`` the per-node CPU-free time.
    ``head`` marks retired slots (fleetsim head-pointer rows; default 0 ==
    plain Ledger).  A full node (``head + n == capacity``) is infeasible.
    """
    K, N = starts.shape
    block_nodes = min(block_nodes, K)
    grid = -(-K // block_nodes)
    pad = grid * block_nodes - K

    def pad_rows(x, fill):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill) if pad else x

    dtype = starts.dtype
    d2 = jnp.asarray(d, dtype).reshape(1, 1)
    col = lambda x, f: pad_rows(jnp.asarray(x, dtype).reshape(K, 1), f)
    ncol = pad_rows(n.astype(jnp.int32).reshape(K, 1), 0)
    hcol = pad_rows(jnp.zeros((K, 1), jnp.int32) if head is None
                    else head.astype(jnp.int32).reshape(K, 1), 0)
    feas, load = pl.pallas_call(
        functools.partial(_fleet_feasibility_kernel, eps=eps),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_nodes, N), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, N), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, N), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_nodes, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_nodes, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid * block_nodes, 1), jnp.int32),
            jax.ShapeDtypeStruct((grid * block_nodes, 1), dtype),
        ],
        interpret=interpret,
    )(d2, pad_rows(starts, BIG), pad_rows(ends, BIG), pad_rows(sizes, 0.0),
      ncol, hcol, col(ps, 0.0), col(cpu_free, 0.0))
    return feas[:K, 0] != 0, load[:K, 0]
