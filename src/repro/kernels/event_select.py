"""Pallas kernel: fused next-event selection + per-hop referral scoring.

The event-time fleet scan (DESIGN.md §7) advances by *events*: at every
step the earliest pending event — the next fresh arrival from the sorted
request stream, or the head of the deferred re-arrival buffer — is
selected, and its node's admission geometry plus the network-priced
feasibility of every forwarding candidate must be known before anything
can be applied.  Unfused that is three passes over the same stacked
``(num_nodes, window)`` ledger tile: the two-way ``(time, seq)`` merge,
the ``link_cost`` wire-delay mask, and the insertion-geometry search.
All three are bandwidth-bound on the ledger block, so this kernel runs
them in one VMEM pass: each grid program

1. resolves the merge (fresh wins ties — the host heap assigns all fresh
   arrivals their sequence numbers before the run, so at equal
   timestamps a fresh arrival always outranks a mid-run push);
2. gathers the selected source node's latency / inverse-bandwidth rows
   (masked one-hot sum — no dynamic addressing) and prices each
   candidate's delayed arrival ``t + lat + payload·inv_bw``;
3. emits, per candidate node: the feasibility bit at that delayed
   arrival, the arrival itself, the insertion slot ``j`` and window edge
   ``cap`` (so the apply step needs no second search), and the pending
   load the routing policies rank by.

The admission geometry (searchsorted-as-masked-count, gap scan, prefix
slack) is identical to the ``fleet_feasibility`` / ``link_cost``
kernels.  Pure-jnp oracle: :func:`repro.kernels.ref.event_select_ref`
(bit-for-bit).  Off-TPU the :mod:`repro.kernels.ops` wrapper runs this
body in interpret mode, lowering to ordinary XLA.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _event_select_kernel(ta_ref, na_ref, da_ref, pa_ref, ya_ref, aa_ref,
                         tb_ref, nb_ref, db_ref, pb_ref, yb_ref, ab_ref,
                         starts_ref, ends_ref, sizes_ref, n_ref, head_ref,
                         speeds_ref, busy_ref, lat_ref, invbw_ref,
                         takea_ref, tsel_ref, nsel_ref,
                         feas_ref, arr_ref, j_ref, cap_ref, load_ref,
                         *, eps: float):
    # -- the merge: earliest of (fresh candidate a, buffer head b); fresh
    # wins ties (host heap seq order — see module docstring)
    avail_a = aa_ref[0, 0] != 0
    avail_b = ab_ref[0, 0] != 0
    take_a = avail_a & ((ta_ref[0, 0] <= tb_ref[0, 0]) | ~avail_b)
    t = jnp.where(take_a, ta_ref[0, 0], tb_ref[0, 0])
    node = jnp.where(take_a, na_ref[0, 0], nb_ref[0, 0])
    d = jnp.where(take_a, da_ref[0, 0], db_ref[0, 0])
    p = jnp.where(take_a, pa_ref[0, 0], pb_ref[0, 0])
    payload = jnp.where(take_a, ya_ref[0, 0], yb_ref[0, 0])

    starts = starts_ref[...]                     # (bk, N)
    ends = ends_ref[...]
    sizes = sizes_ref[...]
    n = n_ref[...]                               # (bk, 1) int32
    head = head_ref[...]                         # (bk, 1) int32
    speeds = speeds_ref[...]                     # (bk, 1)
    busy = busy_ref[...]                         # (bk, 1)
    lat = lat_ref[...]                           # (K, bk)
    invbw = invbw_ref[...]                       # (K, bk)
    bk, N = starts.shape
    K = lat.shape[0]
    tail = head + n
    idx = jax.lax.broadcasted_iota(jnp.int32, (bk, N), 1)
    ps = p / speeds

    # -- source row gather as a one-hot masked sum (the selected node is a
    # traced scalar; exactly one row matches, and 0.0 + v == v exactly)
    rows = jax.lax.broadcasted_iota(jnp.int32, (K, bk), 0)
    lat_row = jnp.sum(jnp.where(rows == node, lat, 0.0), axis=0)[:, None]
    ibw_row = jnp.sum(jnp.where(rows == node, invbw, 0.0), axis=0)[:, None]
    arrive = t + lat_row + payload * ibw_row
    free = jnp.maximum(arrive, busy)

    # -- admission geometry, identical to fleet_feasibility/link_cost:
    # searchsorted on a sorted ledger == masked count; retired slots hold
    # -BIG/0 and count into both sums identically
    cap_idx = jnp.sum((starts < d).astype(jnp.int32), axis=1, keepdims=True)
    e_hi = jnp.sum((ends < d).astype(jnp.int32), axis=1, keepdims=True)

    prev_ends = jnp.concatenate(
        [jnp.full((bk, 1), -BIG, ends.dtype), ends[:, :-1]], axis=1)
    has_gap = (starts > prev_ends) & (idx >= head + 1) & (idx < tail)
    gap_ok = has_gap & (idx <= e_hi)
    prev_gap = jnp.max(jnp.where(gap_ok, idx, head), axis=1, keepdims=True)

    no_straddle = e_hi >= cap_idx
    j = jnp.where(no_straddle, e_hi, prev_gap)
    j_clip = jnp.minimum(j, N - 1)
    start_j = jnp.sum(jnp.where(idx == j_clip, starts, 0.0), axis=1,
                      keepdims=True)
    start_j = jnp.where(j < tail, start_j, BIG)
    cap = jnp.where(no_straddle, d, jnp.minimum(start_j, d))
    start_h = jnp.sum(jnp.where(idx == jnp.minimum(head, N - 1), starts, 0.0),
                      axis=1, keepdims=True)
    start_h = jnp.where(n > 0, start_h, BIG)
    front = (~no_straddle) & (prev_gap == head)
    cap = jnp.where(front, jnp.minimum(start_h, d), cap)
    j = jnp.where(front, head, j)

    pw_j = jnp.sum(jnp.where(idx < j, sizes, 0.0), axis=1, keepdims=True)
    feasible = (cap - (free + pw_j) >= ps - eps) & (cap > free) & (tail < N)

    takea_ref[0, 0] = take_a.astype(jnp.int32)
    tsel_ref[0, 0] = t
    nsel_ref[0, 0] = node
    feas_ref[...] = feasible.astype(jnp.int32)
    arr_ref[...] = arrive
    j_ref[...] = j
    cap_ref[...] = cap
    load_ref[...] = jnp.sum(sizes, axis=1, keepdims=True)


def event_select_fwd(t_a, node_a, d_a, p_a, pay_a, avail_a,
                     t_b, node_b, d_b, p_b, pay_b, avail_b,
                     starts: jnp.ndarray, ends: jnp.ndarray,
                     sizes: jnp.ndarray, n: jnp.ndarray, head,
                     speeds: jnp.ndarray, busy: jnp.ndarray,
                     latency: jnp.ndarray, inv_bw: jnp.ndarray, *,
                     eps: float = 1e-6, block_nodes: int = 8,
                     interpret: bool = True
                     ) -> Tuple[jnp.ndarray, ...]:
    """Two candidate events + stacked (K, N) ledger windows -> the merge
    verdict plus every per-candidate quantity the event step applies.

    Candidate fields are scalars: ``(t, node, d, p, payload, avail)`` for
    the fresh arrival (``_a``) and the re-arrival buffer head (``_b``);
    ``avail`` gates empty streams.  ``latency``/``inv_bw`` are the full
    (K, K) :class:`repro.netsim.NetParams` tensors (pass zeros for a
    network-free run — the diagonal must be zero, so the selected node
    scores itself at its true arrival ``t``).  ``head`` marks retired
    slots (fleetsim head-pointer rows; default 0 == plain Ledger).

    Returns ``(take_fresh, t, node, feasible (K,), arrive (K,), j (K,),
    cap (K,), load (K,))`` — oracle:
    :func:`repro.kernels.ref.event_select_ref`.
    """
    K, N = starts.shape
    block_nodes = min(block_nodes, K)
    grid = -(-K // block_nodes)
    pad = grid * block_nodes - K

    def pad_rows(x, fill):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill) if pad else x

    dtype = starts.dtype
    fscalar = lambda x: jnp.asarray(x, dtype).reshape(1, 1)
    iscalar = lambda x: jnp.asarray(x, jnp.int32).reshape(1, 1)
    col = lambda x, f: pad_rows(jnp.asarray(x, dtype).reshape(K, 1), f)
    ncol = pad_rows(n.astype(jnp.int32).reshape(K, 1), 0)
    hcol = pad_rows(jnp.zeros((K, 1), jnp.int32) if head is None
                    else head.astype(jnp.int32).reshape(K, 1), 0)
    # (K, K) net tensors padded on columns only: each program reads the
    # full row space but just its candidate-block of columns
    net_pad = lambda x: jnp.pad(jnp.asarray(x, dtype), ((0, 0), (0, pad))) \
        if pad else jnp.asarray(x, dtype)
    bs_scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    bs_rows = pl.BlockSpec((block_nodes, N), lambda i: (i, 0))
    bs_col = pl.BlockSpec((block_nodes, 1), lambda i: (i, 0))
    bs_net = pl.BlockSpec((K, block_nodes), lambda i: (0, i))
    KB = grid * block_nodes
    take_a, t_sel, n_sel, feas, arr, j, cap, load = pl.pallas_call(
        functools.partial(_event_select_kernel, eps=eps),
        grid=(grid,),
        in_specs=[bs_scalar] * 12 + [
            bs_rows, bs_rows, bs_rows,           # starts, ends, sizes
            bs_col, bs_col,                      # n, head
            bs_col, bs_col,                      # speeds, busy
            bs_net, bs_net,                      # latency, inv_bw
        ],
        out_specs=[bs_scalar, bs_scalar, bs_scalar,
                   bs_col, bs_col, bs_col, bs_col, bs_col],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((KB, 1), jnp.int32),
            jax.ShapeDtypeStruct((KB, 1), dtype),
            jax.ShapeDtypeStruct((KB, 1), jnp.int32),
            jax.ShapeDtypeStruct((KB, 1), dtype),
            jax.ShapeDtypeStruct((KB, 1), dtype),
        ],
        interpret=interpret,
    )(fscalar(t_a), iscalar(node_a), fscalar(d_a), fscalar(p_a),
      fscalar(pay_a), iscalar(avail_a),
      fscalar(t_b), iscalar(node_b), fscalar(d_b), fscalar(p_b),
      fscalar(pay_b), iscalar(avail_b),
      pad_rows(starts, BIG), pad_rows(ends, BIG), pad_rows(sizes, 0.0),
      ncol, hcol, col(speeds, 1.0), col(busy, 0.0),
      net_pad(latency), net_pad(inv_bw))
    return (take_a[0, 0] != 0, t_sel[0, 0], n_sel[0, 0],
            feas[:K, 0] != 0, arr[:K, 0], j[:K, 0], cap[:K, 0], load[:K, 0])
