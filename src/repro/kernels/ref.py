"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q (B,S,H,D); k,v (B,S,KV,D). fp32 softmax, GQA by head grouping."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= qpos - kpos < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def moe_gemm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Grouped per-expert GEMM: (E,C,d) @ (E,d,f) -> (E,C,f)."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
