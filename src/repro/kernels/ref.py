"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q (B,S,H,D); k,v (B,S,KV,D). fp32 softmax, GQA by head grouping."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= qpos - kpos < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def moe_gemm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Grouped per-expert GEMM: (E,C,d) @ (E,d,f) -> (E,C,f)."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def fleet_feasibility_ref(starts: jnp.ndarray, ends: jnp.ndarray,
                          sizes: jnp.ndarray, n: jnp.ndarray,
                          ps: jnp.ndarray, d: jnp.ndarray,
                          cpu_free: jnp.ndarray, head=None,
                          eps: float = 1e-6):
    """Cross-node ledger feasibility scan + load reduction, pure jnp.

    Stacked (K, N) ledgers, (K,) ``n``/``ps``/``cpu_free``, scalar absolute
    deadline ``d`` -> ((K,) feasible bool incl. the capacity check, (K,)
    pending work).  Same math as ``repro.core.jax_queue._search`` batched
    over the node axis (searchsorted == masked count on sorted ledgers).

    ``head`` supports the fleet simulator's head-pointer rows: slots
    ``[0, head)`` are retired (starts/ends overwritten with -BIG, sizes 0 —
    which keeps the whole row time-sorted and every count/prefix-sum
    valid), live blocks occupy ``[head, head + n)``.  Default 0 == a plain
    :class:`repro.core.jax_queue.Ledger`.
    """
    feas, _, _, load = fleet_search_ref(starts, ends, sizes, n, ps, d,
                                        cpu_free, head, eps)
    return feas, load


def link_cost_ref(starts: jnp.ndarray, ends: jnp.ndarray,
                  sizes: jnp.ndarray, n: jnp.ndarray, ps: jnp.ndarray,
                  d: jnp.ndarray, busy: jnp.ndarray, head,
                  t_src: jnp.ndarray, lat_row: jnp.ndarray,
                  inv_bw_row: jnp.ndarray, payload: jnp.ndarray,
                  eps: float = 1e-6):
    """Fused transfer-cost + queue-feasibility candidate scoring.

    One request sitting at a source node at ``t_src`` is scored against
    K candidate nodes in a single pass: the wire cost of the referral
    (``lat_row + payload * inv_bw_row``, the source's row of the
    :class:`repro.netsim.NetParams` tensors) delays its arrival at each
    candidate, and admission feasibility is evaluated at that delayed
    arrival — so a referral that would eat the deadline slack scores
    infeasible *before* it is made.  ``busy`` is each node's CPU-free
    time; ``head`` supports fleetsim's head-pointer rows like
    :func:`fleet_feasibility_ref`.

    Returns ``((K,) feasible, (K,) arrival time, (K,) pending work)`` —
    the oracle for the Pallas ``link_cost`` kernel.
    """
    K = starts.shape[0]
    arrive = t_src + lat_row.reshape(K) + payload * inv_bw_row.reshape(K)
    free = jnp.maximum(arrive, busy.reshape(K))
    feas, _, _, load = fleet_search_ref(starts, ends, sizes, n, ps, d,
                                        free, head, eps)
    return feas, arrive, load


def event_select_ref(t_a, node_a, d_a, p_a, pay_a, avail_a,
                     t_b, node_b, d_b, p_b, pay_b, avail_b,
                     starts: jnp.ndarray, ends: jnp.ndarray,
                     sizes: jnp.ndarray, n: jnp.ndarray, head,
                     speeds: jnp.ndarray, busy: jnp.ndarray,
                     latency: jnp.ndarray, inv_bw: jnp.ndarray,
                     eps: float = 1e-6):
    """Fused next-event merge + per-hop referral scoring (pure jnp).

    Two candidate events — the next *fresh* arrival (``_a``) from the
    sorted request stream and the head of the deferred *re-arrival*
    buffer (``_b``), each ``(t, node, d, p, payload, avail)`` scalars —
    are merged by ``(time, seq)`` order (fresh wins ties: the host heap
    numbers every fresh arrival before the run, so a mid-run push never
    outranks one at an equal timestamp).  The selected event is then
    scored against all K candidate nodes at its network-delayed arrival
    ``t + latency[node] + payload · inv_bw[node]`` (pass zero tensors
    for a network-free run; the diagonal must be zero so the event's own
    node is scored at its true arrival).

    Returns ``(take_fresh, t, node, feasible (K,), arrive (K,), j (K,),
    cap (K,), load (K,))`` — ``j``/``cap`` are the insertion slot and
    window edge, so the event step applies the admission without a
    second search.  The oracle for the Pallas ``event_select`` kernel.
    """
    K = starts.shape[0]
    avail_a = jnp.asarray(avail_a, bool)
    avail_b = jnp.asarray(avail_b, bool)
    take_a = avail_a & ((jnp.asarray(t_a) <= jnp.asarray(t_b)) | ~avail_b)
    t = jnp.where(take_a, t_a, t_b)
    node = jnp.where(take_a, node_a, node_b)
    d = jnp.where(take_a, d_a, d_b)
    p = jnp.where(take_a, p_a, p_b)
    payload = jnp.where(take_a, pay_a, pay_b)
    ps = p / speeds.reshape(K)
    arrive = t + latency[node].reshape(K) + payload * inv_bw[node].reshape(K)
    free = jnp.maximum(arrive, busy.reshape(K))
    feas, j, cap, load = fleet_search_ref(starts, ends, sizes, n, ps, d,
                                          free, head, eps)
    return take_a, t, node, feas, arrive, j, cap, load


def fleet_search_ref(starts: jnp.ndarray, ends: jnp.ndarray,
                     sizes: jnp.ndarray, n: jnp.ndarray, ps: jnp.ndarray,
                     d: jnp.ndarray, cpu_free: jnp.ndarray, head=None,
                     eps: float = 1e-6):
    """Full admission geometry per row: (feasible, j, cap, load).

    ``j`` is the global insertion slot and ``cap`` the window's right edge
    (the quantities ``jax_queue.push`` computes internally) so a caller can
    apply the insert without a second search pass.
    """
    BIG = 1e30
    K, N = starts.shape
    n = n.reshape(K, 1).astype(jnp.int32)
    head = jnp.zeros((K, 1), jnp.int32) if head is None \
        else head.reshape(K, 1).astype(jnp.int32)
    tail = head + n
    free = jnp.asarray(cpu_free, starts.dtype).reshape(K, 1)
    p = jnp.asarray(ps, starts.dtype).reshape(K, 1)
    idx = jnp.arange(N)[None, :]
    # retired slots hold -BIG and count into both sums identically, so the
    # straddle comparison and the live-relative positions stay consistent
    cap_idx = jnp.sum((starts < d).astype(jnp.int32), axis=1, keepdims=True)
    e_hi = jnp.sum((ends < d).astype(jnp.int32), axis=1, keepdims=True)
    prev_ends = jnp.concatenate(
        [jnp.full((K, 1), -BIG, ends.dtype), ends[:, :-1]], axis=1)
    has_gap = (starts > prev_ends) & (idx >= head + 1) & (idx < tail)
    gap_ok = has_gap & (idx <= e_hi)
    prev_gap = jnp.max(jnp.where(gap_ok, idx, head), axis=1, keepdims=True)
    no_straddle = e_hi >= cap_idx
    j = jnp.where(no_straddle, e_hi, prev_gap)
    start_j = jnp.take_along_axis(starts, jnp.minimum(j, N - 1), axis=1)
    start_j = jnp.where(j < tail, start_j, BIG)
    cap = jnp.where(no_straddle, d, jnp.minimum(start_j, d))
    start_h = jnp.take_along_axis(starts, jnp.minimum(head, N - 1), axis=1)
    start_h = jnp.where(n > 0, start_h, BIG)
    front = ~no_straddle & (prev_gap == head)
    cap = jnp.where(front, jnp.minimum(start_h, d), cap)
    j = jnp.where(front, head, j)
    pw_j = jnp.sum(jnp.where(idx < j, sizes, 0.0), axis=1, keepdims=True)
    feasible = (cap - (free + pw_j) >= p - eps) & (cap > free) & (tail < N)
    return (feasible[:, 0], j[:, 0], cap[:, 0], jnp.sum(sizes, axis=1))
