"""Grouped per-expert GEMM kernel: (E, C, d) x (E, d, f) -> (E, C, f).

The MoE hot spot after dispatch (taxonomy B.2/B.9 "fused MoE GEMM").
Grid = (E, C-tiles, f-tiles); each program computes one (block_c × block_f)
MXU tile from a (block_c × d) activation strip and a (d × block_f) weight
strip, both VMEM-resident.  d strips are loaded whole — for the assigned
configs (d ≤ 7168 bf16) the working set is ≤ ~4 MB, well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_gemm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[0]                                   # (block_c, d)
    w = w_ref[0]                                   # (d, block_f)
    o_ref[0] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def moe_gemm(x: jnp.ndarray, w: jnp.ndarray, *, block_c: int = 128,
             block_f: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x (E, C, d); w (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    f = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    nc = -(-C // block_c)
    nf = -(-f // block_f)
    pad_c = nc * block_c - C
    pad_f = nf * block_f - f
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad_f)))
    out = pl.pallas_call(
        _moe_gemm_kernel,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, ci, fi: (e, ci, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, ci, fi: (e, 0, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, nc * block_c, nf * block_f), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:, :C, :f]
