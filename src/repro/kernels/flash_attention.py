"""FlashAttention forward kernel for TPU (pl.pallas_call + BlockSpec).

TPU-native adaptation of the IO-aware attention idea [arXiv:2205.14135]:

* grid = (batch×heads, Q-blocks, KV-blocks); the KV axis is minor-most, so
  one core revisits the same (bh, qi) output block across ki steps — the
  online-softmax state (m, l, acc) lives in VMEM scratch between steps
  (the canonical TPU accumulation pattern; no atomics, no shared-memory
  reductions as a GPU kernel would use).
* Q/K/V blocks are VMEM-resident tiles of (block_q × D) / (block_k × D);
  D is padded to a multiple of 128 lanes by the wrapper in ops.py.
* causal + sliding-window masks are applied as position bias inside the
  block; GQA is handled by the K/V index_map (q-head h reads kv-head
  h // (H // KV)) so no KV replication is materialized.

Validated against ref.flash_attention_ref in interpret mode (CPU) across
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               window: Optional[int], n_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (bq, D)
    k = k_ref[0].astype(jnp.float32)                   # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = (q_pos < seq_q) & (k_pos < seq_k)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)... stored (bq, 128)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        sm_scale: Optional[float] = None,
                        interpret: bool = True) -> jnp.ndarray:
    """q (BH, S, D); k, v (BH, S, D) — heads already mapped by the wrapper.

    D must be a multiple of 128 (the ops.py wrapper pads; ``sm_scale`` must
    then be the *unpadded* head-dim scale).
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q = -(-Sq // block_q)
    n_k = -(-Sk // block_k)
    pad_q = n_q * block_q - Sq
    pad_k = n_k * block_k - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _fa_kernel, scale=sm_scale if sm_scale is not None else D ** -0.5,
        block_q=block_q, block_k=block_k,
        causal=causal, window=window, n_k=n_k, seq_q=Sq, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, n_q * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # m
            pltpu.VMEM((block_q, 1), jnp.float32),     # l
            pltpu.VMEM((block_q, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
