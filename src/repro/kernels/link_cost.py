"""Pallas kernel: fused transfer-cost + queue-feasibility candidate scoring.

The network-aware forward chain asks, for ONE request at a source node,
"which of the K candidate nodes can still admit it *after* paying the
wire cost of the referral?"  Unfused that is two passes — a (K,) delay
computation (latency row + payload serialization) and the cross-node
ledger feasibility scan of :mod:`repro.kernels.fleet_feasibility` — over
the same stacked ``(num_nodes, capacity)`` ledger tile.  Both are
bandwidth-bound on the ledger block, so this kernel fuses them: each
grid program loads a ``(block_nodes, capacity)`` tile once and emits the
feasibility bit (evaluated at the *delayed* arrival ``max(t_src +
latency + payload·inv_bw, busy)``), the arrival time itself, and the
node's pending work in a single VMEM pass.  A referral that would eat
the deadline slack scores infeasible before it is made — the admission
geometry (searchsorted-as-masked-count, gap scan, prefix slack) is
identical to the fleet-feasibility kernel.

Pure-jnp oracle: :func:`repro.kernels.ref.link_cost_ref` (bit-for-bit on
the feasibility bits).  Off-TPU the :mod:`repro.kernels.ops` wrapper
runs this body in interpret mode, lowering to ordinary XLA.

Status: the event-time fleet scan (DESIGN.md §7) now scores referrals
through :mod:`repro.kernels.event_select`, which fuses this kernel's
wire-delay mask with the next-event merge — ``link_cost`` remains the
standalone one-source-many-candidates primitive (and the parity anchor
for the shared admission geometry) for consumers outside the scan.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _link_cost_kernel(t_ref, payload_ref, d_ref, starts_ref, ends_ref,
                      sizes_ref, n_ref, head_ref, ps_ref, busy_ref, lat_ref,
                      invbw_ref, feas_ref, arr_ref, load_ref, *, eps: float):
    t = t_ref[0, 0]
    payload = payload_ref[0, 0]
    d = d_ref[0, 0]
    starts = starts_ref[...]                     # (bk, N)
    ends = ends_ref[...]
    sizes = sizes_ref[...]
    n = n_ref[...]                               # (bk, 1) int32
    head = head_ref[...]                         # (bk, 1) int32
    ps = ps_ref[...]                             # (bk, 1)
    busy = busy_ref[...]                         # (bk, 1)
    lat = lat_ref[...]                           # (bk, 1)
    invbw = invbw_ref[...]                       # (bk, 1)
    bk, N = starts.shape
    tail = head + n
    idx = jax.lax.broadcasted_iota(jnp.int32, (bk, N), 1)

    # the fused part: referral wire cost delays the arrival, and the
    # admission window opens at the later of (arrival, CPU-free) — same
    # association order as the oracle so the bits match exactly
    arrive = t + lat + payload * invbw
    free = jnp.maximum(arrive, busy)

    # admission geometry — identical to fleet_feasibility: searchsorted on
    # a sorted ledger == masked count; retired slots hold -BIG/0 and count
    # into both sums identically
    cap_idx = jnp.sum((starts < d).astype(jnp.int32), axis=1, keepdims=True)
    e_hi = jnp.sum((ends < d).astype(jnp.int32), axis=1, keepdims=True)

    prev_ends = jnp.concatenate(
        [jnp.full((bk, 1), -BIG, ends.dtype), ends[:, :-1]], axis=1)
    has_gap = (starts > prev_ends) & (idx >= head + 1) & (idx < tail)
    gap_ok = has_gap & (idx <= e_hi)
    prev_gap = jnp.max(jnp.where(gap_ok, idx, head), axis=1, keepdims=True)

    no_straddle = e_hi >= cap_idx
    j = jnp.where(no_straddle, e_hi, prev_gap)
    j_clip = jnp.minimum(j, N - 1)
    start_j = jnp.sum(jnp.where(idx == j_clip, starts, 0.0), axis=1,
                      keepdims=True)
    start_j = jnp.where(j < tail, start_j, BIG)
    cap = jnp.where(no_straddle, d, jnp.minimum(start_j, d))
    start_h = jnp.sum(jnp.where(idx == jnp.minimum(head, N - 1), starts, 0.0),
                      axis=1, keepdims=True)
    start_h = jnp.where(n > 0, start_h, BIG)
    front = (~no_straddle) & (prev_gap == head)
    cap = jnp.where(front, jnp.minimum(start_h, d), cap)
    j = jnp.where(front, head, j)

    pw_j = jnp.sum(jnp.where(idx < j, sizes, 0.0), axis=1, keepdims=True)
    feasible = (cap - (free + pw_j) >= ps - eps) & (cap > free) & (tail < N)
    feas_ref[...] = feasible.astype(jnp.int32)
    arr_ref[...] = arrive
    load_ref[...] = jnp.sum(sizes, axis=1, keepdims=True)


def link_cost_fwd(starts: jnp.ndarray, ends: jnp.ndarray, sizes: jnp.ndarray,
                  n: jnp.ndarray, ps: jnp.ndarray, d: jnp.ndarray,
                  busy: jnp.ndarray, head, t_src: jnp.ndarray,
                  lat_row: jnp.ndarray, inv_bw_row: jnp.ndarray,
                  payload: jnp.ndarray, *, eps: float = 1e-6,
                  block_nodes: int = 8, interpret: bool = True
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stacked (K, N) ledgers + the source's (K,) latency / inverse-
    bandwidth rows -> ((K,) feasible bool, (K,) arrival, (K,) load).

    ``t_src`` is the time the request sits at the source, ``payload`` its
    frame size (both scalars); ``busy`` the per-node CPU-free floor
    (``max`` with the delayed arrival happens inside).  ``head`` marks
    retired slots (fleetsim head-pointer rows; default 0 == plain
    Ledger).  A full node (``head + n == capacity``) is infeasible.
    """
    K, N = starts.shape
    block_nodes = min(block_nodes, K)
    grid = -(-K // block_nodes)
    pad = grid * block_nodes - K

    def pad_rows(x, fill):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill) if pad else x

    dtype = starts.dtype
    scalar = lambda x: jnp.asarray(x, dtype).reshape(1, 1)
    col = lambda x, f: pad_rows(jnp.asarray(x, dtype).reshape(K, 1), f)
    ncol = pad_rows(n.astype(jnp.int32).reshape(K, 1), 0)
    hcol = pad_rows(jnp.zeros((K, 1), jnp.int32) if head is None
                    else head.astype(jnp.int32).reshape(K, 1), 0)
    blockspec_rows = pl.BlockSpec((block_nodes, N), lambda i: (i, 0))
    blockspec_col = pl.BlockSpec((block_nodes, 1), lambda i: (i, 0))
    blockspec_scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    feas, arr, load = pl.pallas_call(
        functools.partial(_link_cost_kernel, eps=eps),
        grid=(grid,),
        in_specs=[
            blockspec_scalar,            # t_src
            blockspec_scalar,            # payload
            blockspec_scalar,            # d
            blockspec_rows,              # starts
            blockspec_rows,              # ends
            blockspec_rows,              # sizes
            blockspec_col,               # n
            blockspec_col,               # head
            blockspec_col,               # ps
            blockspec_col,               # busy
            blockspec_col,               # lat_row
            blockspec_col,               # inv_bw_row
        ],
        out_specs=[blockspec_col, blockspec_col, blockspec_col],
        out_shape=[
            jax.ShapeDtypeStruct((grid * block_nodes, 1), jnp.int32),
            jax.ShapeDtypeStruct((grid * block_nodes, 1), dtype),
            jax.ShapeDtypeStruct((grid * block_nodes, 1), dtype),
        ],
        interpret=interpret,
    )(scalar(t_src), scalar(payload), scalar(d),
      pad_rows(starts, BIG), pad_rows(ends, BIG), pad_rows(sizes, 0.0),
      ncol, hcol, col(ps, 0.0), col(busy, 0.0), col(lat_row, 0.0),
      col(inv_bw_row, 0.0))
    return feas[:K, 0] != 0, arr[:K, 0], load[:K, 0]
