"""Unified orchestration API — one event-driven core behind the simulator
and the serving engine.

Compose four pieces:

* :class:`Topology`     — who forwards to whom, and per-node speed factors;
* :class:`Workload`     — pluggable arrival processes + scenario registry;
* :class:`Router`       — topology-aware forwarding strategies;
* :class:`Orchestrator` — the single event-heap engine (admission,
  sequential forwarding, hooks, per-node/per-service metrics).

Quick start::

    from repro.core.block_queue import FastPreferentialQueue
    from repro.orchestration import (Orchestrator, Router, Topology,
                                     get_workload)

    topo = Topology.ring(6, speeds=[1, 1, 1, 2, 2, 4])
    orch = Orchestrator(topo, FastPreferentialQueue,
                        Router(topo, "power_of_two", seed=0))
    result = orch.run(get_workload("paper/scenario3").generate(seed=0))
    print(result.met_rate, result.per_service["S1"].met_rate)

See DESIGN.md §4 for the full API reference and the migration table from
the legacy ``SimConfig`` fields.
"""
from repro.orchestration.orchestrator import (Hooks, Orchestrator,
                                              OrchestratorResult,
                                              ServiceStats, place)
from repro.orchestration.router import ROUTER_POLICIES, Router
from repro.orchestration.topology import Topology
from repro.orchestration.workload import (DiurnalWorkload, PoissonWorkload,
                                          TraceWorkload, UniformWorkload,
                                          Workload, available_workloads,
                                          dump_trace, get_workload,
                                          register_workload)

__all__ = [
    "Hooks", "Orchestrator", "OrchestratorResult", "ServiceStats", "place",
    "ROUTER_POLICIES", "Router", "Topology",
    "DiurnalWorkload", "PoissonWorkload", "TraceWorkload", "UniformWorkload",
    "Workload", "available_workloads", "dump_trace", "get_workload",
    "register_workload",
]
