"""Topology-aware forwarding router.

The legacy policies (``repro.core.policies``) hardcode the paper's
fully-connected cluster: candidates are "every node but me".  The
:class:`Router` keeps the same four strategies but draws candidates from
``topology.neighbors(node)``, so the identical policy code drives a mesh, a
ring, a star, or a two-tier cluster.  On a full mesh with the ``random``
policy it consumes its rng stream exactly like the legacy
``RandomPolicy`` — that is what keeps the simulator adapter golden-value
equivalent to the pre-refactor event loop.

Strategies (``Router(topology, policy=...)``):

* ``random``           — uniform over neighbors (the paper's SFA step);
* ``power_of_two``     — sample two neighbors, keep the less loaded;
* ``least_loaded``     — full neighbor scan, minimum pending work;
* ``round_robin``      — deterministic cycling over *stable node ids* (the
  pointer indexes the global id space and skips non-neighbors, so the
  rotation never shifts meaning when the excluded node changes);
* ``batched_feasible`` — score every neighbor's admission ledger in one
  device call (:func:`repro.core.jax_queue.feasible_nodes`, the cross-node
  companion of ``feasible_batch``) and pick the least-loaded neighbor that
  can still meet the request's deadline; falls back to ``least_loaded``
  order when nobody can.

Routed objects only need ``.queue`` (``pending_work()``, and
``scheduled_blocks()`` for ``batched_feasible``) and, for
``batched_feasible``, ``cpu_free_time(now)`` — both
:class:`repro.core.node.MECNode` and the serving engine's replicas qualify.
"""
from __future__ import annotations

import random
from typing import List, Optional, Sequence, Tuple

from repro.core.request import Request
from repro.orchestration.topology import Topology

ROUTER_POLICIES = ("random", "power_of_two", "least_loaded", "round_robin",
                   "batched_feasible")


class Router:
    """Pick a forwarding target among a node's topology neighbors.

    ``network`` (a :class:`repro.netsim.LinkModel`) makes the
    ``batched_feasible`` policy network-aware: each candidate is scored
    at its *delayed* arrival ``now + transfer_delay(src, cand, service)``,
    so a neighbor whose wire cost would eat the deadline slack is not
    chosen even if its queue alone could admit.  The other policies never
    read ledger state and are unaffected.
    """

    def __init__(self, topology: Topology, policy: str = "random",
                 rng: Optional[random.Random] = None, seed: int = 0,
                 network=None, forward_delay: float = 0.0):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"options: {sorted(ROUTER_POLICIES)}")
        self.topology = topology
        self.policy = policy
        self.network = network
        # the orchestrator's fixed per-forward delay: scored alongside the
        # wire cost so feasibility sees the true re-arrival time (the
        # orchestrator syncs it when it injects its network)
        self.forward_delay = float(forward_delay)
        self.rng = rng if rng is not None else random.Random(seed)
        self._rr = 0                         # stable-id round-robin pointer

    # -- public API ----------------------------------------------------------
    def candidate_ids(self, src: int) -> Tuple[int, ...]:
        return self.topology.neighbors(src)

    def choose_id(self, nodes: Sequence, src: int, *,
                  request: Optional[Request] = None,
                  now: float = 0.0) -> int:
        """Return the id of the forwarding target for a request at ``src``.

        ``nodes`` must be indexed by topology node id.
        """
        cand_ids = self.topology.neighbors(src)
        if not cand_ids:
            raise ValueError(f"node {src} has no neighbors to forward to")
        return getattr(self, f"_{self.policy}")(nodes, src, cand_ids,
                                                request, now)

    def choose(self, nodes: Sequence, src: int, *,
               request: Optional[Request] = None, now: float = 0.0):
        """Like :meth:`choose_id` but returns the node object."""
        return nodes[self.choose_id(nodes, src, request=request, now=now)]

    # -- strategies ----------------------------------------------------------
    @staticmethod
    def _load(node) -> float:
        return node.queue.pending_work()

    def _random(self, nodes, src, cand_ids, request, now) -> int:
        return self.rng.choice(cand_ids)

    def _power_of_two(self, nodes, src, cand_ids, request, now) -> int:
        if len(cand_ids) == 1:
            return cand_ids[0]
        a, b = self.rng.sample(cand_ids, 2)
        return a if self._load(nodes[a]) <= self._load(nodes[b]) else b

    def _least_loaded(self, nodes, src, cand_ids, request, now) -> int:
        return min(cand_ids,
                   key=lambda i: (self._load(nodes[i]), self.rng.random()))

    def _round_robin(self, nodes, src, cand_ids, request, now) -> int:
        n = self.topology.n_nodes
        neighbors = set(cand_ids)
        for _ in range(n):
            cand = self._rr % n
            self._rr += 1
            if cand in neighbors:
                return cand
        raise AssertionError("unreachable: cand_ids is non-empty")

    def _batched_feasible(self, nodes, src, cand_ids, request, now) -> int:
        if request is None:
            return self._least_loaded(nodes, src, cand_ids, request, now)
        # per-candidate processing time: fast nodes need less of the window
        ps = [request.proc_time / self.topology.speed(i) for i in cand_ids]
        # network-aware: the request reaches each candidate only after the
        # forward delay plus the referral's wire time, so feasibility is
        # scored at that arrival (matching the orchestrator's heap event)
        if self.network is not None:
            arrivals = [now + self.forward_delay
                        + self.network.transfer_delay(src, i,
                                                      request.service)
                        for i in cand_ids]
        else:
            arrivals = [now] * len(cand_ids)
        feasible = dict(zip(cand_ids, _score_feasible(
            nodes, cand_ids, ps, request.deadline, arrivals)))
        ranked = sorted(cand_ids, key=lambda i: (self._load(nodes[i]), i))
        for i in ranked:
            if feasible[i]:
                return i
        return ranked[0]                      # nobody feasible: least loaded


# ---------------------------------------------------------------------------
# Device-batched feasibility scoring
# ---------------------------------------------------------------------------
def _score_feasible(nodes, cand_ids: Sequence[int], ps: Sequence[float],
                    deadline: float, arrivals: Sequence[float]) -> List[bool]:
    """One admission-feasibility bit per candidate (``ps`` holds the
    request's speed-scaled processing time, ``arrivals`` its per-candidate
    arrival time — they differ under a network model), via a single
    stacked device call when JAX is available (host fallback otherwise)."""
    blocks = []
    frees = []
    for i, arr in zip(cand_ids, arrivals):
        node = nodes[i]
        free = node.cpu_free_time(arr) if hasattr(node, "cpu_free_time") \
            else arr
        frees.append(free)
        blocks.append(node.queue.scheduled_blocks(free)
                      if hasattr(node.queue, "scheduled_blocks") else [])
    try:
        import jax.numpy as jnp
        import numpy as np
        from repro.core import jax_queue as jq
    except Exception:                        # pragma: no cover - no-JAX host
        return [_host_feasible(b, p, deadline, f)
                for b, p, f in zip(blocks, ps, frees)]

    cap = max(8, max((len(b) for b in blocks), default=0) + 1)
    cap = 1 << (cap - 1).bit_length()        # pow2 => few jit retraces
    K = len(cand_ids)
    ns = []
    h_starts = np.full((K, cap), jq.BIG, np.float32)
    h_ends = np.full((K, cap), jq.BIG, np.float32)
    h_sizes = np.zeros((K, cap), np.float32)
    for k, blist in enumerate(blocks):
        for j, (s, e) in enumerate(blist):
            h_starts[k, j] = s
            h_ends[k, j] = e
            h_sizes[k, j] = e - s
        ns.append(len(blist))
    leds = jq.Ledger(starts=jnp.asarray(h_starts), ends=jnp.asarray(h_ends),
                     sizes=jnp.asarray(h_sizes),
                     n=jnp.asarray(ns, jnp.int32))
    ok = jq.feasible_nodes(leds, jnp.asarray(ps, jnp.float32),
                           jnp.float32(deadline),
                           jnp.asarray(frees, jnp.float32))
    return [bool(v) for v in np.asarray(ok)]


def _host_feasible(blocks: Sequence[Tuple[float, float]], p: float, d: float,
                   cpu_free: float) -> bool:
    """Pure-python mirror of jax_queue's ledger test (gap search +
    cumulative-slack feasibility)."""
    n = len(blocks)
    starts = [b[0] for b in blocks]
    ends = [b[1] for b in blocks]
    e_hi = sum(1 for e in ends if e < d)
    cap_idx = next((i for i, s in enumerate(starts) if s >= d), n)
    if e_hi >= cap_idx:
        j, cap = e_hi, d
    else:
        j = 0
        for i in range(e_hi, 0, -1):
            if starts[i] > ends[i - 1]:
                j = i
                break
        cap = min(starts[j], d) if n else d
    pw = sum(e - s for s, e in blocks[:j])
    return cap > cpu_free and cap - (cpu_free + pw) >= p - 1e-6
