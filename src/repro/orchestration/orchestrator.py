"""The single event-driven orchestration core.

One engine implements the paper's strategy — deadline-aware admission +
sequential forwarding (§III) — for every consumer:

* :func:`repro.core.simulator.run_simulation` is a thin adapter over
  :class:`Orchestrator` (golden-value guarded: identical results to the
  pre-refactor event loop on the paper configs);
* the serving engine places live requests with :func:`place`, the
  synchronous single-request variant of the same admit/forward loop;
* new experiments drive :class:`Orchestrator` directly with any
  :class:`~repro.orchestration.topology.Topology` /
  :class:`~repro.orchestration.workload.Workload` /
  :class:`~repro.orchestration.router.Router` combination.

Heterogeneity: a node with ``topology.speed(i) = s`` processes every request
``s``-times faster — admission and execution both use the scaled processing
time, while SLA deadlines stay untouched.  The caller's request objects are
never mutated by the scaling (a scaled shadow copy rides through the queue;
completion results are copied back).

Observability: :class:`Hooks` exposes the four decision points of the
strategy (admit / forward / force / discard) plus completion, and
:class:`OrchestratorResult` carries per-node and per-service metric
breakdowns next to the headline aggregates.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import statistics
from typing import Callable, Dict, List, Optional, Sequence, TYPE_CHECKING

from repro.core.node import MECNode, NodeMetrics, QueueLike
from repro.core.request import Request, Service
from repro.orchestration.router import Router
from repro.orchestration.topology import Topology

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.netsim.link import LinkModel

_ARRIVAL, _COMPLETE = 0, 1


@dataclasses.dataclass
class Hooks:
    """Optional callbacks at the strategy's decision points.

    Signatures::

        on_admit(request, node, now, forced)    # admitted (forced = ran late)
        on_forward(request, src_node, dst_node, now)
        on_discard(request, node, now)          # discard_on_exhaust variant
        on_complete(request, node, now)
    """
    on_admit: Optional[Callable] = None
    on_forward: Optional[Callable] = None
    on_discard: Optional[Callable] = None
    on_complete: Optional[Callable] = None


@dataclasses.dataclass
class ServiceStats:
    """Per-service-class outcome counters."""
    total: int = 0
    processed: int = 0
    met_deadline: int = 0
    discarded: int = 0
    response_sum: float = 0.0

    @property
    def met_rate(self) -> float:
        return self.met_deadline / max(1, self.total)

    @property
    def mean_response_time(self) -> float:
        return self.response_sum / max(1, self.processed)


@dataclasses.dataclass
class OrchestratorResult:
    total_requests: int
    processed: int
    met_deadline: int
    forwards: int
    discarded: int
    mean_response_time: float
    end_time: float
    events: int
    per_node: List[NodeMetrics]
    per_service: Dict[str, ServiceStats]
    completed: List[Request]
    transfer_time: float = 0.0        # total wire time spent on referrals

    @property
    def met_rate(self) -> float:
        return self.met_deadline / max(1, self.total_requests)


class Orchestrator:
    """Event-heap engine: deadline-aware admission + sequential forwarding.

    ``queue_factory`` builds one admission queue per node (e.g.
    ``FastPreferentialQueue``).  The event loop mirrors the paper's §IV
    semantics exactly — arrival events try admission at the target node;
    rejects forward ``max_forwards`` times through the router; exhausted
    requests are force-pushed (or discarded under the Beraldi variant).

    ``network`` (a :class:`repro.netsim.LinkModel`) prices every referral:
    the forwarded request re-arrives ``transfer_delay(src, dst, service)``
    later while its absolute deadline stays put, so the wire time comes
    straight out of the admission slack — a referral can *cause* a miss.
    The router inherits the same model for network-aware feasibility
    scoring.  ``network=None`` (and the zero model) reproduce the
    network-free event stream exactly (DESIGN.md §6).
    """

    def __init__(self, topology: Topology,
                 queue_factory: Callable[[], QueueLike],
                 router: Optional[Router] = None, *,
                 max_forwards: int = 2,
                 forward_delay: float = 0.0,
                 discard_on_exhaust: bool = False,
                 hooks: Optional[Hooks] = None,
                 network: Optional["LinkModel"] = None):
        self.topology = topology
        self.router = router if router is not None else Router(topology)
        if self.router.topology is not topology:
            raise ValueError("router and orchestrator topology must match")
        self.network = network
        if network is not None:
            if network.n_nodes != topology.n_nodes:
                raise ValueError(f"network prices {network.n_nodes} nodes "
                                 f"for a {topology.n_nodes}-node topology")
            # the router's feasibility scoring must see the same wire
            # costs AND forward delay the heap events pay (no-op unless
            # batched_feasible)
            if self.router.network is None:
                self.router.network = network
            elif self.router.network is not network:
                raise ValueError("router and orchestrator price different "
                                 "networks; pass one LinkModel to both (or "
                                 "only to the orchestrator)")
            self.router.forward_delay = forward_delay
        self.max_forwards = max_forwards
        self.forward_delay = forward_delay
        self.discard_on_exhaust = discard_on_exhaust
        self.hooks = hooks or Hooks()
        self._queue_factory = queue_factory
        # Rebuilt at the top of every run() so the orchestrator is reusable;
        # kept as an attribute for post-run introspection (hooks receive
        # these node objects).
        self.nodes = [MECNode(i, queue_factory())
                      for i in range(topology.n_nodes)]
        self._scaled_services: Dict[tuple, Service] = {}
        self._originals: Dict[int, Request] = {}

    # -- speed scaling -------------------------------------------------------
    def _scaled(self, req: Request, speed: float) -> Request:
        """Shadow copy whose proc_time is scaled by the node speed (same rid,
        same absolute deadline)."""
        key = (req.service.name, req.service.proc_time, speed)
        svc = self._scaled_services.get(key)
        if svc is None:
            svc = dataclasses.replace(req.service,
                                      proc_time=req.service.proc_time / speed)
            self._scaled_services[key] = svc
        return Request(service=svc, arrival_time=req.arrival_time,
                       origin_node=req.origin_node, rid=req.rid,
                       forwards=req.forwards)

    def _try_admit(self, node: MECNode, req: Request, now: float,
                   forced: bool) -> bool:
        speed = self.topology.speed(node.node_id)
        if speed == 1.0:
            return node.try_admit(req, now, forced=forced)
        shadow = self._scaled(req, speed)
        ok = node.try_admit(shadow, now, forced=forced)
        if ok:
            self._originals[shadow.rid] = req
        return ok

    # -- event loop ----------------------------------------------------------
    def run(self, requests: Sequence[Request]) -> OrchestratorResult:
        # fresh node/queue state per run: busy_until and metrics must not
        # leak from a previous run on the same orchestrator
        self.nodes = [MECNode(i, self._queue_factory())
                      for i in range(self.topology.n_nodes)]
        self._originals.clear()
        nodes = self.nodes
        hooks = self.hooks
        seq = itertools.count()
        heap: List = []
        for req in requests:
            heapq.heappush(heap, (req.arrival_time, next(seq), _ARRIVAL, req,
                                  nodes[req.origin_node]))

        forwards = 0
        transfer_time = 0.0
        discarded_reqs: List[Request] = []
        completed: List[Request] = []
        events = 0
        end_time = 0.0
        network = self.network

        def dispatch(node: MECNode, now: float) -> None:
            started = node.start_next(now)
            if started is not None:
                heapq.heappush(heap, (node.busy_until, next(seq), _COMPLETE,
                                      started, node))

        while heap:
            now, _, kind, req, node = heapq.heappop(heap)
            events += 1
            end_time = now
            if kind == _COMPLETE:
                node.complete(now)
                orig = self._originals.pop(req.rid, None)
                if orig is not None:
                    orig.completion_time = req.completion_time
                    orig.served_by = req.served_by
                    req = orig
                completed.append(req)
                if hooks.on_complete:
                    hooks.on_complete(req, node, now)
                dispatch(node, now)
                continue

            # ARRIVAL
            node.metrics.received += 1
            exhausted = (req.forwards >= self.max_forwards
                         or self.topology.degree(node.node_id) == 0)
            forced = exhausted and not self.discard_on_exhaust
            if self._try_admit(node, req, now, forced=forced):
                if hooks.on_admit:
                    hooks.on_admit(req, node, now, forced)
                dispatch(node, now)
            elif exhausted:
                discarded_reqs.append(req)
                node.metrics.discarded += 1
                if hooks.on_discard:
                    hooks.on_discard(req, node, now)
            else:
                req.forwards += 1
                forwards += 1
                node.metrics.forwards_out += 1
                target = self.router.choose(nodes, node.node_id,
                                            request=req, now=now)
                # the referral rides the transport network: the request
                # re-arrives after the wire time, its deadline unmoved —
                # the transfer consumes exactly that much admission slack
                delay = self.forward_delay
                if network is not None:
                    hop = network.transfer_delay(node.node_id,
                                                 target.node_id, req.service)
                    delay += hop
                    transfer_time += hop
                heapq.heappush(heap, (now + delay, next(seq),
                                      _ARRIVAL, req, target))
                if hooks.on_forward:
                    hooks.on_forward(req, node, target, now)

        met = sum(1 for r in completed if r.met_deadline)
        resp = [r.completion_time - r.arrival_time for r in completed
                if r.completion_time is not None]
        return OrchestratorResult(
            total_requests=len(requests),
            processed=len(completed),
            met_deadline=met,
            forwards=forwards,
            discarded=len(discarded_reqs),
            mean_response_time=statistics.fmean(resp) if resp else 0.0,
            end_time=end_time,
            events=events,
            per_node=[n.metrics for n in nodes],
            per_service=_per_service(requests, completed, discarded_reqs),
            completed=completed,
            transfer_time=transfer_time,
        )


def _per_service(requests: Sequence[Request], completed: Sequence[Request],
                 discarded: Sequence[Request]) -> Dict[str, ServiceStats]:
    stats: Dict[str, ServiceStats] = {}
    for r in requests:
        stats.setdefault(r.service.name, ServiceStats()).total += 1
    for r in completed:
        s = stats.setdefault(r.service.name, ServiceStats())
        s.processed += 1
        if r.met_deadline:
            s.met_deadline += 1
        if r.completion_time is not None:
            s.response_sum += r.completion_time - r.arrival_time
    for r in discarded:
        stats.setdefault(r.service.name, ServiceStats()).discarded += 1
    return stats


# ---------------------------------------------------------------------------
# Synchronous placement — the serving engine's entry into the same strategy.
# ---------------------------------------------------------------------------
def place(request, origin: int, nodes: Sequence, router: Router, *,
          now: float, max_forwards: int,
          admit: Callable[[object, object, float, bool], bool],
          discard_on_exhaust: bool = False,
          on_forward: Optional[Callable] = None):
    """Admit-or-forward a single live request, synchronously (zero network
    delay), until it is admitted, force-pushed, or discarded.

    ``nodes`` must be indexed by topology node id; ``admit(node, request,
    now, forced)`` performs the actual admission attempt (so callers bring
    their own node type — MECNode, ServingReplica, ...).  ``request`` only
    needs a mutable integer ``forwards`` attribute.

    Returns ``(outcome, node)`` with outcome in {"admitted", "discarded"}.
    """
    idx = origin
    while True:
        target = nodes[idx]
        exhausted = (request.forwards >= max_forwards
                     or router.topology.degree(idx) == 0)
        forced = exhausted and not discard_on_exhaust
        if admit(target, request, now, forced):
            return "admitted", target
        if exhausted:
            return "discarded", target
        request.forwards += 1
        nxt = router.choose_id(nodes, idx, request=request, now=now)
        if on_forward:
            on_forward(request, target, nodes[nxt], now)
        idx = nxt
