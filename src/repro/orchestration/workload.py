"""Pluggable arrival processes + the scenario registry.

The paper evaluates exactly three fixed scenarios (Table II) under an
i.i.d. uniform arrival window.  :class:`Workload` generalizes that: a
workload is any object that deterministically maps a seed to a request
list, so the same orchestration core can be driven by

* :class:`UniformWorkload`   — the paper's process (per-(node, service)
  counts, uniform arrivals over a window);
* :class:`PoissonWorkload`   — per-(node, service) Poisson streams over a
  horizon, the standard queueing-theory arrival model;
* :class:`DiurnalWorkload`   — uniform counts modulated by a sinusoidal
  intensity (thinning), modelling daily peaks / bursty load;
* :class:`TraceWorkload`     — replay of a recorded JSONL trace
  (``{"service": "S1", "arrival_time": 12.5, "node": 0}`` per line).

The module-level **registry** replaces grabbing ``SCENARIOS[i]`` directly:
the paper's three scenarios are pre-registered as ``paper/scenario{1,2,3}``
(guaranteed to generate streams identical to
:func:`repro.core.scenarios.generate_requests`), and experiments register
their own named workloads next to them.
"""
from __future__ import annotations

import json
import math
import random
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.request import Request, SERVICES, SERVICE_ORDER, Service
from repro.core.scenarios import DEFAULT_ARRIVAL_WINDOW, SCENARIOS


class Workload:
    """Deterministic seed -> request-list generator."""

    name: str = "workload"
    n_nodes: int = 1

    def generate(self, seed: int) -> List[Request]:
        raise NotImplementedError

    def total_requests(self, seed: int = 0) -> int:
        return len(self.generate(seed))

    def to_arrays(self, seed: int = 0, payload_fn=None):
        """Pack ``generate(seed)`` into the fleet simulator's tensor form.

        Returns ``(RequestArrays, service name table)`` — the arrival-sorted
        per-request tensors :func:`repro.fleetsim.simulate` scans, with
        ``service`` ids indexing the returned name tuple.  Stack the arrays
        of several seeds (``jax.tree.map`` + ``jnp.stack``) to vmap a whole
        seed sweep in one device call.  ``payload_fn`` overrides the wire
        payload model (netsim; only read when a ``NetParams`` is passed).
        """
        from repro.fleetsim.arrays import pack_requests
        arrays, names, _ = pack_requests(self.generate(seed),
                                         payload_fn=payload_fn)
        return arrays, names

    @staticmethod
    def _finish(requests: List[Request]) -> List[Request]:
        requests.sort(key=lambda r: (r.arrival_time, r.rid))
        return requests


class UniformWorkload(Workload):
    """The paper's arrival process: fixed per-(node, service) counts with
    i.i.d. uniform arrival times over ``[0, window]``.

    ``seed_key`` salts the rng stream.  The pre-registered paper scenarios
    pass their scenario number so that ``generate(seed)`` reproduces
    :func:`repro.core.scenarios.generate_requests` bit-for-bit (guarded by
    tests/test_workload.py).
    """

    def __init__(self, counts: Sequence[Dict[str, int]],
                 window: float = DEFAULT_ARRIVAL_WINDOW,
                 services: Optional[Dict[str, Service]] = None,
                 name: str = "uniform", seed_key=None):
        self.counts = [dict(c) for c in counts]
        self.window = float(window)
        self.services = dict(services or SERVICES)
        self.name = name
        self.n_nodes = len(self.counts)
        self._seed_key = seed_key if seed_key is not None else name

    def _service_order(self) -> Sequence[str]:
        if all(s in self.services for s in SERVICE_ORDER) and \
                len(self.services) == len(SERVICE_ORDER):
            return SERVICE_ORDER
        return sorted(self.services)

    def generate(self, seed: int) -> List[Request]:
        if isinstance(self._seed_key, int):
            # Legacy parity path: generate_requests seeds from an int-only
            # tuple hash (process-stable); the paper scenarios rely on it.
            rng = random.Random((self._seed_key, seed, round(self.window)).__hash__())
        else:
            # str seeds hash via sha512 — stable across processes, unlike
            # tuple.__hash__ of a str-bearing tuple (PYTHONHASHSEED).
            rng = random.Random(
                f"uniform:{self._seed_key}:{seed}:{round(self.window)}")
        requests: List[Request] = []
        for node_idx, counts in enumerate(self.counts):
            for sname in self._service_order():
                svc = self.services[sname]
                for _ in range(counts.get(sname, 0)):
                    requests.append(Request(
                        service=svc,
                        arrival_time=rng.uniform(0.0, self.window),
                        origin_node=node_idx,
                    ))
        return self._finish(requests)


class PoissonWorkload(Workload):
    """Independent Poisson streams per (node, service) over ``[0, horizon]``.

    ``rates[node][service]`` is in requests per unit time.  Use
    :meth:`from_counts` to match a count table's expected volume
    (``rate = count / horizon``), which makes Poisson-vs-uniform an
    apples-to-apples arrival-process ablation.
    """

    def __init__(self, rates: Sequence[Dict[str, float]], horizon: float,
                 services: Optional[Dict[str, Service]] = None,
                 name: str = "poisson"):
        self.rates = [dict(r) for r in rates]
        self.horizon = float(horizon)
        self.services = dict(services or SERVICES)
        self.name = name
        self.n_nodes = len(self.rates)

    @classmethod
    def from_counts(cls, counts: Sequence[Dict[str, int]], horizon: float,
                    services: Optional[Dict[str, Service]] = None,
                    name: str = "poisson") -> "PoissonWorkload":
        rates = [{s: c / horizon for s, c in node.items()} for node in counts]
        return cls(rates, horizon, services=services, name=name)

    def generate(self, seed: int) -> List[Request]:
        rng = random.Random(f"poisson:{self.name}:{seed}:{round(self.horizon)}")
        requests: List[Request] = []
        for node_idx, rates in enumerate(self.rates):
            for sname in sorted(rates):
                rate = rates[sname]
                if rate <= 0:
                    continue
                svc = self.services[sname]
                t = rng.expovariate(rate)
                while t <= self.horizon:
                    requests.append(Request(service=svc, arrival_time=t,
                                            origin_node=node_idx))
                    t += rng.expovariate(rate)
        return self._finish(requests)


class DiurnalWorkload(Workload):
    """Fixed counts with arrivals drawn from a sinusoidal intensity.

    The intensity over ``[0, window]`` is
    ``lambda(t) = 1 + amplitude * sin(2*pi*peaks*t/window)`` (thinning /
    rejection sampling), so ``peaks`` bursts of height ``1 + amplitude``
    alternate with troughs of ``1 - amplitude``.  ``amplitude=0``
    degenerates to :class:`UniformWorkload`.
    """

    def __init__(self, counts: Sequence[Dict[str, int]],
                 window: float = DEFAULT_ARRIVAL_WINDOW,
                 peaks: int = 2, amplitude: float = 0.8,
                 services: Optional[Dict[str, Service]] = None,
                 name: str = "diurnal"):
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
        self.counts = [dict(c) for c in counts]
        self.window = float(window)
        self.peaks = peaks
        self.amplitude = amplitude
        self.services = dict(services or SERVICES)
        self.name = name
        self.n_nodes = len(self.counts)

    def _sample_arrival(self, rng: random.Random) -> float:
        lam_max = 1.0 + self.amplitude
        while True:
            t = rng.uniform(0.0, self.window)
            lam = 1.0 + self.amplitude * math.sin(
                2.0 * math.pi * self.peaks * t / self.window)
            if rng.random() * lam_max <= lam:
                return t

    def generate(self, seed: int) -> List[Request]:
        rng = random.Random(
            f"diurnal:{self.name}:{seed}:{self.peaks}:{round(self.window)}")
        requests: List[Request] = []
        for node_idx, counts in enumerate(self.counts):
            for sname in sorted(counts):
                svc = self.services[sname]
                for _ in range(counts[sname]):
                    requests.append(Request(
                        service=svc,
                        arrival_time=self._sample_arrival(rng),
                        origin_node=node_idx,
                    ))
        return self._finish(requests)


class TraceWorkload(Workload):
    """Replay a recorded JSONL trace (seed is ignored — a trace is a trace).

    Line format: ``{"service": "S1", "arrival_time": 12.5, "node": 0}``.
    Unknown service names raise at load; see :func:`dump_trace` for the
    symmetric writer.
    """

    def __init__(self, path: str,
                 services: Optional[Dict[str, Service]] = None,
                 name: Optional[str] = None):
        self.path = path
        self.services = dict(services or SERVICES)
        self.name = name or f"trace:{path}"
        self._records: List[Dict] = []
        with open(path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if rec["service"] not in self.services:
                    raise ValueError(
                        f"{path}:{lineno}: unknown service {rec['service']!r}")
                self._records.append(rec)
        self.n_nodes = 1 + max((r["node"] for r in self._records), default=0)

    def generate(self, seed: int = 0) -> List[Request]:
        requests = [Request(service=self.services[r["service"]],
                            arrival_time=float(r["arrival_time"]),
                            origin_node=int(r["node"]))
                    for r in self._records]
        return self._finish(requests)


def dump_trace(requests: Sequence[Request], path: str) -> None:
    """Write a request list as a JSONL trace readable by TraceWorkload."""
    with open(path, "w") as f:
        for r in requests:
            f.write(json.dumps({"service": r.service.name,
                                "arrival_time": r.arrival_time,
                                "node": r.origin_node}) + "\n")


# ---------------------------------------------------------------------------
# Scenario registry — the named-workload successor of the SCENARIOS dict.
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], Workload]] = {}


def register_workload(name: str, factory: Callable[[], Workload],
                      overwrite: bool = False) -> None:
    """Register a zero-arg workload factory under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"workload {name!r} already registered")
    _REGISTRY[name] = factory


def get_workload(name: str) -> Workload:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(f"unknown workload {name!r}; "
                         f"options: {available_workloads()}") from None


def available_workloads() -> List[str]:
    return sorted(_REGISTRY)


def _register_paper_scenarios() -> None:
    for s, counts in SCENARIOS.items():
        # seed_key=s reproduces generate_requests(s, seed) exactly.
        register_workload(
            f"paper/scenario{s}",
            (lambda counts=counts, s=s: UniformWorkload(
                counts, window=DEFAULT_ARRIVAL_WINDOW,
                name=f"paper/scenario{s}", seed_key=s)),
            overwrite=True)


_register_paper_scenarios()
