"""Cluster topology: which nodes can forward to which, and how fast each is.

The paper assumes a fully-connected cluster of identical MEC nodes, so its
forwarding step is "pick any node but me".  Real MEC deployments (the
ETSI-MEP / NetEdge architectures in PAPERS.md) are neither fully connected
nor homogeneous: forwarding is constrained by the transport network and
nodes span several hardware generations.  :class:`Topology` captures both
degrees of freedom:

* **neighbor graph** — an undirected graph over node ids; a router only ever
  forwards to ``topology.neighbors(node)``.  Constructors cover the common
  shapes: :meth:`full_mesh` (the paper), :meth:`ring`, :meth:`star`, and
  :meth:`two_tier` (edge sites backed by a cloud tier).
* **per-node speed** — node ``i`` processes a request in
  ``proc_time / speed(i)``.  ``speed == 1.0`` is the paper's homogeneous
  baseline; a two-tier cluster typically gives the cloud tier ``speed > 1``.

A topology is immutable after construction; it is shared by the
:class:`~repro.orchestration.router.Router`, the
:class:`~repro.orchestration.orchestrator.Orchestrator`, and the serving
engine.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple


class Topology:
    """Undirected neighbor graph + per-node speed factors."""

    def __init__(self, n_nodes: int,
                 edges: Optional[Iterable[Tuple[int, int]]] = None,
                 speeds: Optional[Sequence[float]] = None,
                 name: str = "custom"):
        if n_nodes < 1:
            raise ValueError(f"need at least one node, got {n_nodes}")
        self.n_nodes = n_nodes
        self.name = name
        if speeds is None:
            speeds = [1.0] * n_nodes
        if len(speeds) != n_nodes:
            raise ValueError(f"{len(speeds)} speeds for {n_nodes} nodes")
        if any(s <= 0 for s in speeds):
            raise ValueError("speeds must be positive")
        self._speeds = tuple(float(s) for s in speeds)

        adj: Dict[int, set] = {i: set() for i in range(n_nodes)}
        if edges is None:                      # full mesh
            for i in range(n_nodes):
                adj[i] = set(range(n_nodes)) - {i}
        else:
            for u, v in edges:
                if not (0 <= u < n_nodes and 0 <= v < n_nodes):
                    raise ValueError(f"edge ({u}, {v}) out of range")
                if u == v:
                    continue
                adj[u].add(v)
                adj[v].add(u)
        # sorted tuples => deterministic candidate order for seeded routers
        self._neighbors = tuple(tuple(sorted(adj[i])) for i in range(n_nodes))

    # -- queries ------------------------------------------------------------
    def neighbors(self, node_id: int) -> Tuple[int, ...]:
        """Forwarding candidates of ``node_id``, ascending, self excluded."""
        return self._neighbors[node_id]

    def speed(self, node_id: int) -> float:
        return self._speeds[node_id]

    @property
    def speeds(self) -> Tuple[float, ...]:
        return self._speeds

    @property
    def homogeneous(self) -> bool:
        return all(s == 1.0 for s in self._speeds)

    def degree(self, node_id: int) -> int:
        return len(self._neighbors[node_id])

    def edges(self) -> Tuple[Tuple[int, int], ...]:
        """Canonical (u < v) edge list."""
        return tuple((u, v) for u in range(self.n_nodes)
                     for v in self._neighbors[u] if u < v)

    def __repr__(self) -> str:
        return (f"Topology({self.name!r}, n={self.n_nodes}, "
                f"edges={len(self.edges())}, "
                f"speeds={'homogeneous' if self.homogeneous else self._speeds})")

    # -- constructors --------------------------------------------------------
    @classmethod
    def full_mesh(cls, n_nodes: int,
                  speeds: Optional[Sequence[float]] = None) -> "Topology":
        """Every node is a neighbor of every other node (the paper's model)."""
        return cls(n_nodes, edges=None, speeds=speeds, name="full_mesh")

    @classmethod
    def ring(cls, n_nodes: int,
             speeds: Optional[Sequence[float]] = None) -> "Topology":
        """Node ``i`` is connected to ``i±1 (mod n)``."""
        edges = [(i, (i + 1) % n_nodes) for i in range(n_nodes)]
        return cls(n_nodes, edges=edges, speeds=speeds, name="ring")

    @classmethod
    def star(cls, n_nodes: int, hub: int = 0,
             speeds: Optional[Sequence[float]] = None) -> "Topology":
        """All leaves connect only to ``hub``."""
        edges = [(hub, i) for i in range(n_nodes) if i != hub]
        return cls(n_nodes, edges=edges, speeds=speeds, name="star")

    @classmethod
    def two_tier(cls, n_edge: int, n_cloud: int = 1,
                 edge_speed: float = 1.0,
                 cloud_speed: float = 4.0) -> "Topology":
        """Edge sites backed by a (faster) cloud tier.

        Nodes ``0 .. n_edge-1`` are edge sites; ``n_edge .. n_edge+n_cloud-1``
        are cloud nodes.  Every edge site connects to every cloud node, and
        cloud nodes form a mesh among themselves; edge sites do NOT talk to
        each other directly (the transport network routes through the core).
        """
        n = n_edge + n_cloud
        edges = [(e, n_edge + c) for e in range(n_edge) for c in range(n_cloud)]
        edges += [(n_edge + a, n_edge + b)
                  for a in range(n_cloud) for b in range(a + 1, n_cloud)]
        speeds = [edge_speed] * n_edge + [cloud_speed] * n_cloud
        return cls(n, edges=edges, speeds=speeds, name="two_tier")
